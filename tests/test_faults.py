"""Deterministic chaos suite: fault injection + failure-domain handling.

Every test here is an exact discrete-event scenario under ``ManualClock``
with a seeded/explicit ``FaultPlan`` — expected latencies, health
transitions, and retry schedules are worked out by hand, not read back
from the router. The suite also runs under ``python -O`` in CI (the
chaos-smoke step): none of the failure handling may live in ``assert``
statements (see ``scripts/check_no_bare_assert.py``).

Timing conventions used throughout: ``scripted_pool`` replicas serve one
wave in ``service_s`` starting at ``max(now, busy_until)``; a retried
wave's attempt k re-dispatches after ``retry_backoff_ms * 2**(k-1)``;
wave deadlines are ``submit_t + wave_timeout_mult * work_estimate``.
"""

import math

import numpy as np
import pytest

from repro.obs import Tracer, chrome_json
from repro.serve import (
    DEFAULT_OUTPUT_BOUND,
    AsyncEngine,
    CorruptWave,
    FaultError,
    FaultPlan,
    FaultSpec,
    FaultyModel,
    ManualClock,
    NoReplicaAvailable,
    ReplicaPool,
    Router,
    RouterConfig,
    ServiceModel,
    SyncEngine,
    WaveError,
    faulty_pool,
    wave_integrity_ok,
)
from repro.serve.replica import (
    HEALTHY,
    QUARANTINED,
    RECOVERING,
    SUSPECT,
)
from repro.serve.sim import scripted_pool as _pool


def _svc(service_s):
    """A ServiceModel whose full-wave estimate is exactly ``service_s``
    (works out to sec_per_cycle * 9 cycles for a 2-wide wave)."""
    return ServiceModel(works=[("s", 0)], sec_per_cycle=service_s / 9)


def _x(i=1):
    return np.full((4,), i, np.int32)       # scripted row sum = 4*i


# ---------------------------------------------------------------------------
# the fault plan: matching, consumption, seeding
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("power_surge", wave=1)
    with pytest.raises(ValueError, match="needs a key"):
        FaultSpec("replica_crash")
    with pytest.raises(ValueError, match="factor"):
        FaultSpec("replica_slowdown", wave=1, factor=0.0)


def test_fault_plan_matching_and_consumption():
    plan = FaultPlan([
        FaultSpec("transient_submit_error", replica=0, wave=2),
        FaultSpec("replica_slowdown", replica=1, after_t=0.01,
                  until_t=0.02, factor=3.0),
    ])
    # wave-keyed: fires on (replica 0, wave 2) exactly once
    assert plan.active(0, 1, now=0.0) == []
    assert plan.active(1, 2, now=0.0) == []          # wrong replica
    (hit,) = plan.active(0, 2, now=0.0)
    assert hit.kind == "transient_submit_error"
    assert plan.active(0, 2, now=0.0) == []          # consumed
    # window-keyed slowdown: modifier, never consumed, half-open window
    assert plan.active(1, 9, now=0.009) == []
    assert len(plan.active(1, 9, now=0.01)) == 1
    assert len(plan.active(1, 10, now=0.015)) == 1   # still live
    assert plan.active(1, 11, now=0.02) == []        # until_t exclusive
    plan.reset()
    assert len(plan.active(0, 2, now=0.0)) == 1      # re-armed


def test_chaos_plan_is_a_pure_function_of_its_seed():
    a = FaultPlan.chaos(seed=42, n_replicas=3, horizon_s=1.0, n_faults=6)
    b = FaultPlan.chaos(seed=42, n_replicas=3, horizon_s=1.0, n_faults=6)
    assert [repr(s) for s in a.specs] == [repr(s) for s in b.specs]
    c = FaultPlan.chaos(seed=43, n_replicas=3, horizon_s=1.0, n_faults=6)
    assert [repr(s) for s in a.specs] != [repr(s) for s in c.specs]
    for s in a.specs:
        assert 0 <= s.replica < 3 and 0.0 <= s.after_t < 1.0


def test_wave_integrity_guard():
    assert wave_integrity_ok(np.zeros((2, 3), np.float32))
    assert wave_integrity_ok(np.full((2,), 2.0 ** 24))   # bound inclusive
    assert not wave_integrity_ok(np.asarray([1.0, np.inf]))
    assert not wave_integrity_ok(np.asarray([1.0, np.nan]))
    assert not wave_integrity_ok(np.asarray([2.0 ** 26]))
    assert not wave_integrity_ok(np.asarray([-(1 << 26)], np.int64))
    assert wave_integrity_ok(np.zeros((0,)))             # empty wave
    assert wave_integrity_ok(np.asarray([100.0]), bound=100.0)
    assert not wave_integrity_ok(np.asarray([101.0]), bound=100.0)
    assert DEFAULT_OUTPUT_BOUND == float(1 << 24)


# ---------------------------------------------------------------------------
# wave timeout -> cancel -> retry on another replica (hand-computed)
# ---------------------------------------------------------------------------

def test_wave_timeout_retried_on_other_replica_exact_timing():
    """mb=2, 10ms service, two replicas, deadline = 3x estimate = 30ms,
    backoff 0.5ms. Wave 1 (replica 0) loses its response: the router
    cancels it at t=30ms, re-dispatches to replica 1 at t=30.5ms, and the
    wave completes at t=40.5ms. Latency = 40.5ms from the ORIGINAL
    arrival; replica 0 is suspect; nothing was shed."""
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("wave_timeout", replica=0, wave=1)])
    pool = _pool(clock, [0.010, 0.010], plan=plan)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=1.0, wave_timeout_mult=3.0,
                     retry_backoff_ms=0.5, max_retries=2),
        clock=clock, service_models={"m": _svc(0.010)},
        engine=AsyncEngine())
    reqs = [router.submit("m", _x(), arrival_t=0.0) for _ in range(2)]
    router.drain()
    assert clock.now() == pytest.approx(0.0405)
    for r in reqs:
        assert not r.shed and r.error is None
        assert r.done_t == pytest.approx(0.0405)
        assert r.result[0] == pytest.approx(4.0)     # row sum intact
    r0, r1 = pool.replicas
    assert (r0.health, r1.health) == (SUSPECT, HEALTHY)
    assert r0.last_failure == "WaveTimeout"
    snap = router.stats()["m"]["metrics"]
    assert snap.fault_counts == {"timeout": 1}
    assert snap.n_shed == 0
    # the lost wave burned replica 0's device time but was never served
    assert len(r0.model.calls) == 1 and len(r1.model.calls) == 1


def test_result_arriving_before_deadline_is_served_not_failed():
    """A deadline must only fire for waves that are actually late: with
    service 10ms and deadline 30ms nothing times out and timing matches
    the no-faults run bit-for-bit."""
    clock = ManualClock()
    pool = _pool(clock, [0.010])
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=1.0, wave_timeout_mult=3.0),
        clock=clock, service_models={"m": _svc(0.010)},
        engine=AsyncEngine())
    reqs = [router.submit("m", _x(), arrival_t=0.0) for _ in range(2)]
    router.drain()
    assert clock.now() == pytest.approx(0.010)
    assert all(r.done_t == pytest.approx(0.010) for r in reqs)
    assert router.stats()["m"]["metrics"].fault_counts == {}


# ---------------------------------------------------------------------------
# replica crash mid-burst: zero admitted requests lost (ISSUE acceptance)
# ---------------------------------------------------------------------------

def test_crash_mid_burst_loses_zero_admitted_requests():
    """Eight requests (four waves) on two replicas; replica 0 crashes on
    its second submission. Every admitted request is served, results stay
    bit-exact vs the scripted row sums, and the crash shows up only as a
    fault count + a suspect replica — never a lost request.

    Hand schedule: wave1->r0 (done 10ms), wave2->r1 (10ms), wave3->r0
    CRASHES at submit (parked, backoff 0.5ms, excluded from r0), wave4->r1
    (20ms); retry of wave3 lands on r1 behind its queue -> done 30ms."""
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("replica_crash", replica=0, wave=2,
                                duration_s=0.05)])
    pool = _pool(clock, [0.010, 0.010], plan=plan)
    router = Router({"m": pool},
                    RouterConfig(max_wait_ms=1.0, retry_backoff_ms=0.5),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", _x(i), arrival_t=0.0) for i in range(8)]
    router.drain()
    assert not any(r.shed for r in reqs)             # zero lost
    for i, r in enumerate(reqs):
        assert r.result is not None
        assert float(r.result[0]) == pytest.approx(4.0 * i)  # bit-exact
    done_ms = [r.done_t * 1e3 for r in reqs]
    np.testing.assert_allclose(
        done_ms, [10, 10, 10, 10, 30, 30, 20, 20], rtol=1e-9)
    r0, r1 = pool.replicas
    # r0 went suspect at the crash, but its wave 1 — already in flight —
    # completed clean at 10ms, and any success heals: it ends healthy
    # with the crash on record
    assert r0.health == HEALTHY and r0.last_failure == "ReplicaCrashed"
    assert r1.health == HEALTHY
    snap = router.stats()["m"]["metrics"]
    assert snap.fault_counts == {"submit_error": 1}
    assert snap.n_completed == 8 and snap.n_shed == 0


# ---------------------------------------------------------------------------
# health state machine: suspect -> quarantined -> probe -> healthy
# ---------------------------------------------------------------------------

def test_quarantine_probe_readmission_cycle():
    """Two failures quarantine replica 0; after ``probe_interval`` one
    probe wave is let through, and its success readmits the replica."""
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("replica_crash", replica=0, wave=1,
                                duration_s=0.005)])
    pool = _pool(clock, [0.010, 0.010], plan=plan)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=1.0, retry_backoff_ms=1.0,
                     max_retries=3, probe_interval_ms=20.0),
        clock=clock, engine=AsyncEngine())
    assert pool.probe_interval_s == pytest.approx(0.020)
    r0, r1 = pool.replicas

    # failure 1: wave 1 -> r0 crashes at submit -> suspect
    router.submit("m", _x(), arrival_t=0.0)
    router.submit("m", _x(), arrival_t=0.0)
    assert r0.health == SUSPECT
    # failure 2: the next fresh wave prefers r0 (fewest dispatches tie ->
    # index) and finds it still inside the 5ms outage -> quarantined
    clock.advance(0.001)
    router.step()                       # re-dispatches the retry onto r1
    router.submit("m", _x(), arrival_t=clock.now())
    router.submit("m", _x(), arrival_t=clock.now())
    assert r0.health == QUARANTINED
    assert r0.next_probe_t == pytest.approx(0.001 + 0.020)
    assert pool.n_available == 1
    router.drain()
    assert r0.health == QUARANTINED     # drain served everything via r1

    # probe: past next_probe_t the quarantined replica takes exactly one
    # wave (recovering), and the outage being over, it succeeds -> healthy
    if clock.now() < 0.025:
        clock.advance(0.025 - clock.now())
    router.submit("m", _x(), arrival_t=clock.now())
    router.submit("m", _x(), arrival_t=clock.now())
    assert r0.health == RECOVERING
    router.drain()
    assert r0.health == HEALTHY and r0.n_failures == 0
    assert pool.n_available == 2
    assert len(r0.model.calls) == 1     # the probe is its only served wave


def test_failed_probe_requarantines_with_new_backoff():
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("replica_crash", replica=0, wave=1,
                                duration_s=math.inf)])   # never recovers
    pool = _pool(clock, [0.010, 0.010], plan=plan)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=1.0, retry_backoff_ms=1.0,
                     max_retries=3, probe_interval_ms=10.0),
        clock=clock, engine=AsyncEngine())
    r0 = pool.replicas[0]
    router.submit("m", _x(), arrival_t=0.0)     # fresh wave -> r0 crash
    router.submit("m", _x(), arrival_t=0.0)
    clock.advance(0.001)
    router.step()                               # retry -> r1
    router.submit("m", _x(), arrival_t=clock.now())   # 2nd failure on r0
    router.submit("m", _x(), arrival_t=clock.now())
    assert r0.health == QUARANTINED
    first_probe_t = r0.next_probe_t
    router.drain()
    while clock.now() < first_probe_t:
        clock.advance(first_probe_t - clock.now())
    router.submit("m", _x(), arrival_t=clock.now())   # probe wave -> fails
    router.submit("m", _x(), arrival_t=clock.now())
    router.drain()
    assert r0.health == QUARANTINED             # probe failed, back inside
    assert r0.next_probe_t > first_probe_t      # backoff rescheduled
    assert len(r0.model.calls) == 0             # never served a wave
    # every admitted request still landed (via replica 1)
    assert router.stats()["m"]["metrics"].n_shed == 0


# ---------------------------------------------------------------------------
# all replicas quarantined: typed fast-fail, never a hang (acceptance)
# ---------------------------------------------------------------------------

def test_place_raises_typed_error_when_pool_fully_quarantined():
    clock = ManualClock()
    pool = _pool(clock, [0.010])
    pool.replicas[0].health = QUARANTINED
    pool.replicas[0].next_probe_t = 10.0        # probe far in the future
    with pytest.raises(NoReplicaAvailable, match="replica0=quarantined"):
        pool.place(0.0, now=0.0)
    assert isinstance(NoReplicaAvailable("x"), FaultError)


def test_fully_quarantined_pool_sheds_with_no_replica_reason():
    clock = ManualClock()
    pool = _pool(clock, [0.010])
    pool.replicas[0].health = QUARANTINED
    pool.replicas[0].next_probe_t = 10.0
    router = Router({"m": pool}, RouterConfig(max_wait_ms=1.0),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", _x(), arrival_t=0.0) for _ in range(2)]
    router.drain()                               # returns immediately
    assert clock.now() == 0.0                    # no hang, no busy-wait
    for r in reqs:
        assert r.shed and r.error.startswith("no_replica")
    snap = router.stats()["m"]["metrics"]
    assert snap.shed_reasons == {"no_replica": 2}


def test_pool_probe_interval_validation():
    clock = ManualClock()
    with pytest.raises(ValueError, match="probe_interval_s"):
        _pool(clock, [0.01], probe_interval_s=0.0)


# ---------------------------------------------------------------------------
# corrupt output: integrity guard -> retry, never served
# ---------------------------------------------------------------------------

def test_corrupt_output_is_retried_and_counted():
    """Replica 0's first wave comes back with magnitudes past the proven
    2**24 bound; the guard fails it at settle (t=10ms), the retry lands on
    replica 1 at 10.5ms and completes clean at 20.5ms."""
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("corrupt_output", replica=0, wave=1)])
    pool = _pool(clock, [0.010, 0.010], plan=plan)
    router = Router({"m": pool},
                    RouterConfig(max_wait_ms=1.0, retry_backoff_ms=0.5),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", _x(3), arrival_t=0.0) for _ in range(2)]
    router.drain()
    assert clock.now() == pytest.approx(0.0205)
    for r in reqs:
        assert not r.shed
        assert float(r.result[0]) == pytest.approx(12.0)   # clean retry
    snap = router.stats()["m"]["metrics"]
    assert snap.fault_counts == {"integrity": 1}
    assert pool.replicas[0].last_failure == "CorruptWave"
    assert isinstance(CorruptWave("x"), FaultError)


def test_integrity_check_can_be_disabled():
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("corrupt_output", replica=0, wave=1)])
    pool = _pool(clock, [0.010], plan=plan)
    router = Router({"m": pool},
                    RouterConfig(max_wait_ms=1.0, integrity_check=False),
                    clock=clock, engine=AsyncEngine())
    req = router.submit("m", _x(), arrival_t=0.0)
    router.submit("m", _x(), arrival_t=0.0)
    router.drain()
    # guard off: the corrupt value sails through (the legacy behavior)
    assert float(req.result[0]) > 2.0 ** 24


# ---------------------------------------------------------------------------
# transient submit errors + retry exhaustion
# ---------------------------------------------------------------------------

def test_transient_submit_error_retries_in_place_on_single_replica():
    """With one replica the exclude set is a preference, not a law: the
    retry goes back to the (suspect) sole replica and succeeds."""
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("transient_submit_error", replica=0,
                                wave=1)])
    pool = _pool(clock, [0.010], plan=plan)
    router = Router({"m": pool},
                    RouterConfig(max_wait_ms=1.0, retry_backoff_ms=0.5),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", _x(2), arrival_t=0.0) for _ in range(2)]
    router.drain()
    assert clock.now() == pytest.approx(0.0105)
    for r in reqs:
        assert not r.shed and float(r.result[0]) == pytest.approx(8.0)
    r0 = pool.replicas[0]
    assert r0.health == HEALTHY          # success healed the suspect state
    assert r0.model.n_attempts == 2 and len(r0.model.calls) == 1


def test_retries_exhausted_sheds_with_typed_reason():
    """Both replicas fail every submission inside the window: attempt 0,
    retry 1, retry 2 all fail and ``max_retries=2`` sheds the wave with
    reason "retries_exhausted" — a terminal verdict, not a hang."""
    clock = ManualClock()
    plan = FaultPlan([
        FaultSpec("transient_submit_error", replica=0, after_t=0.0,
                  n_times=10),
        FaultSpec("transient_submit_error", replica=1, after_t=0.0,
                  n_times=10),
    ])
    pool = _pool(clock, [0.010, 0.010], plan=plan)
    router = Router({"m": pool},
                    RouterConfig(max_wait_ms=1.0, retry_backoff_ms=0.5,
                                 max_retries=2),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", _x(), arrival_t=0.0) for _ in range(2)]
    router.drain()
    for r in reqs:
        assert r.shed and r.error.startswith("retries_exhausted")
        assert r.result is None
    snap = router.stats()["m"]["metrics"]
    assert snap.shed_reasons == {"retries_exhausted": 2}
    assert snap.fault_counts["submit_error"] == 3     # 1 + 2 retries
    assert snap.n_completed == 0


# ---------------------------------------------------------------------------
# slowdown: a modifier, not a failure
# ---------------------------------------------------------------------------

def test_slowdown_stretches_service_without_counting_as_fault():
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("replica_slowdown", replica=0,
                                after_t=0.0, factor=3.0)])
    pool = _pool(clock, [0.010], plan=plan)
    router = Router({"m": pool}, RouterConfig(max_wait_ms=1.0),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", _x(), arrival_t=0.0) for _ in range(2)]
    router.drain()
    assert clock.now() == pytest.approx(0.030)       # 10ms x 3
    assert all(not r.shed for r in reqs)
    assert router.stats()["m"]["metrics"].fault_counts == {}


# ---------------------------------------------------------------------------
# drain terminates with never-completing waves in flight (satellite)
# ---------------------------------------------------------------------------

def test_drain_terminates_when_inflight_wave_never_completes():
    """Deadlines OFF (the legacy config): a lost scripted wave has
    ``ready_t = inf``; drain's blocking reap must fast-fail it typed
    (WaveTimeout -> retry -> success) instead of sleeping forever."""
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("wave_timeout", replica=0, wave=1)])
    pool = _pool(clock, [0.010], plan=plan)
    router = Router({"m": pool},
                    RouterConfig(max_wait_ms=1.0, retry_backoff_ms=0.5),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", _x(), arrival_t=0.0) for _ in range(2)]
    router.drain()                                   # must return
    assert math.isfinite(clock.now())
    for r in reqs:
        assert not r.shed and r.result is not None
    assert router.stats()["m"]["metrics"].fault_counts == {"timeout": 1}


def test_drain_terminates_when_every_retry_is_lost_too():
    """Worst case: every wave the sole replica ever runs is lost and
    deadlines are off. The retry budget still bounds the episode — after
    failure -> suspect -> quarantined the pool is empty and the wave is
    shed typed. Drain returns; nothing hangs."""
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("wave_timeout", replica=0, after_t=0.0,
                                n_times=50)])
    pool = _pool(clock, [0.010], plan=plan)
    router = Router({"m": pool},
                    RouterConfig(max_wait_ms=1.0, retry_backoff_ms=0.5,
                                 max_retries=5),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", _x(), arrival_t=0.0) for _ in range(2)]
    router.drain()
    assert math.isfinite(clock.now())
    assert all(r.shed for r in reqs)
    reasons = router.stats()["m"]["metrics"].shed_reasons
    assert sum(reasons.values()) == 2 and "no_replica" in reasons


# ---------------------------------------------------------------------------
# degraded-capacity admission: priced to the surviving pool
# ---------------------------------------------------------------------------

def test_admission_reprices_to_surviving_pool_when_replica_quarantined():
    """The two-replica admission scenario from test_serve_async admits all
    six requests; with replica 0 quarantined the same offered load must
    shed the last two — the pool really is half itself. est = max_wait +
    ceil((inflight+1)/1)*service: r0/r1 12ms, r2/r3 22ms, r4/r5 32ms >
    25ms -> shed."""
    clock = ManualClock()
    pool = _pool(clock, [0.010, 0.010])
    pool.replicas[0].health = QUARANTINED
    pool.replicas[0].next_probe_t = 10.0
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=2.0, p99_budget_ms=25.0),
        clock=clock, service_models={"m": _svc(0.010)},
        engine=AsyncEngine())
    reqs = [router.submit("m", _x(), arrival_t=0.0) for _ in range(6)]
    assert [r.shed for r in reqs] == [False] * 4 + [True] * 2
    router.drain()
    served = [r for r in reqs if not r.shed]
    np.testing.assert_allclose([r.latency_s for r in served],
                               [0.010, 0.010, 0.020, 0.020], rtol=1e-9)
    assert len(pool.replicas[0].model.calls) == 0    # quarantine held


# ---------------------------------------------------------------------------
# determinism: byte-identical chaos traces (ISSUE acceptance)
# ---------------------------------------------------------------------------

def _chaos_run(seed=11):
    from repro.serve import poisson_trace

    clock = ManualClock()
    tracer = Tracer(clock=clock)
    plan = FaultPlan.chaos(seed=seed, n_replicas=2, horizon_s=0.08,
                           n_faults=5)
    pool = _pool(clock, [0.003, 0.003], plan=plan)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=2.0, wave_timeout_mult=3.0,
                     retry_backoff_ms=0.5, max_retries=2),
        clock=clock, service_models={"m": _svc(0.003)},
        tracer=tracer, engine=AsyncEngine())
    reqs = router.run_trace("m", poisson_trace(qps=300.0, n=40, seed=5),
                            lambda i: _x(i))
    return tracer, router, reqs


def test_chaos_run_exports_byte_identical_event_log():
    tr1, router1, reqs1 = _chaos_run()
    tr2, router2, reqs2 = _chaos_run()
    s1 = chrome_json(tr1, **router1.trace_names())
    s2 = chrome_json(tr2, **router2.trace_names())
    assert s1 == s2                       # byte-identical chaos replay
    assert len(tr1) > 0
    # the chaos actually happened (non-vacuous): some fault fired
    snap = router1.stats()["m"]["metrics"]
    assert sum(snap.fault_counts.values()) > 0
    # and every request reached a verdict: served or typed shed
    for r1, r2 in zip(reqs1, reqs2):
        assert (r1.shed, r1.done_t, r1.error) == (r2.shed, r2.done_t,
                                                  r2.error)
        assert r1.shed or r1.result is not None


# ---------------------------------------------------------------------------
# the real path: FaultyModel around a compiled executor
# ---------------------------------------------------------------------------

def test_faulty_model_injects_on_real_submit_wave_path():
    """``faulty_pool`` wraps a compiled golden model; a corrupt first wave
    is caught by the integrity guard and retried, and the surviving
    results are bit-exact vs ``offline`` — the acceptance bar."""
    import jax.numpy as jnp

    from repro.deploy import compile_graph
    from tests.test_serve import _load

    graph, x = _load("kws")
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    y_off = np.asarray(cm.offline(jnp.asarray(x)))
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("corrupt_output", replica=0, wave=1)])
    pool = faulty_pool(ReplicaPool(cm), plan, clock=clock)
    assert isinstance(pool.replicas[0].model, FaultyModel)
    assert pool.default_micro_batch == cm.default_micro_batch  # passthrough
    router = Router({"kws": pool},
                    RouterConfig(max_wait_ms=1.0, micro_batch=2,
                                 retry_backoff_ms=0.5),
                    clock=clock, engine=SyncEngine())
    reqs = [router.submit("kws", np.asarray(x[i]), arrival_t=0.0)
            for i in range(2)]
    router.drain()
    fm = pool.replicas[0].model
    assert fm.n_injected == 1 and fm.n_attempts == 2
    for i, r in enumerate(reqs):
        assert not r.shed
        np.testing.assert_array_equal(np.asarray(r.result), y_off[i])
    snap = router.stats()["kws"]["metrics"]
    assert snap.fault_counts == {"integrity": 1}


def test_faulty_model_crash_outage_expires():
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("replica_crash", replica=0, wave=1,
                                duration_s=0.5)])

    class _Echo:
        default_micro_batch = 4

        def submit_wave(self, x, valid=None, micro_batch=None):
            x = np.asarray(x)
            mb = int(micro_batch or self.default_micro_batch)
            n = x.shape[0]
            mask = np.concatenate([np.ones(n, bool),
                                   np.zeros(mb - n, bool)])
            y = np.zeros((mb,) + x.shape[1:], np.float32)
            y[:n] = x
            return y, mask

    fm = FaultyModel(_Echo(), plan, replica=0, clock=clock)
    from repro.serve.faults import ReplicaCrashed

    with pytest.raises(ReplicaCrashed):
        fm.submit_wave(np.ones((2, 3)))
    clock.advance(0.2)
    with pytest.raises(ReplicaCrashed):        # still inside the outage
        fm.submit_wave(np.ones((2, 3)))
    clock.advance(0.4)                         # outage over
    y, mask = fm.submit_wave(np.ones((2, 3)))
    assert mask.tolist() == [True, True, False, False]
    assert fm.n_attempts == 3


# ---------------------------------------------------------------------------
# typed executor errors (satellite: WaveError wrapping)
# ---------------------------------------------------------------------------

def test_executor_wraps_execution_failures_as_wave_error():
    import jax.numpy as jnp

    from repro.deploy import compile_graph
    from tests.test_serve import _load

    graph, x = _load("kws")
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    # sanity: the happy path still works after the wrapping change
    y, mask = cm.submit_wave(jnp.asarray(x[:2]), micro_batch=4)
    assert mask.tolist() == [True, True, False, False]
    # break the compiled segment pipeline underneath submit_wave: the
    # escaping exception must come back as the typed WaveError (a
    # FaultError the router retries), not a raw backend error
    cm.segments = None
    with pytest.raises(WaveError, match="compiled segment pipeline"):
        cm.submit_wave(jnp.asarray(x[:2]), micro_batch=4)
    # input validation is NOT wrapped — caller bugs stay ValueErrors
    cm2 = compile_graph(graph, in_scale=graph.meta["in_scale"],
                        use_pallas=False)
    with pytest.raises(ValueError):
        cm2.submit_wave(jnp.asarray(x[:3]), micro_batch=2)
