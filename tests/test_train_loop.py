"""Fault-tolerant training loop: crash/resume, straggler watchdog -> elastic
restart, checkpoint cadence — all with injected faults."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpoint import latest_step
from repro.train.loop import ElasticRestart, LoopConfig, LoopResult, run_training


def _toy_setup():
    """A deterministic 'training': state is a counter, step adds batch sum."""

    def train_step(state, batch):
        new = {"x": state["x"] + jnp.sum(batch)}
        return new, {"loss": jnp.sum(batch)}

    init_state = {"x": jnp.zeros(())}

    def batch_fn(step):
        return jnp.asarray([float(step)])

    return train_step, init_state, batch_fn


def test_runs_to_completion(tmp_path):
    train_step, init_state, batch_fn = _toy_setup()
    cfg = LoopConfig(total_steps=20, ckpt_every=5, ckpt_dir=str(tmp_path),
                     log_every=5)
    res = run_training(train_step, init_state, batch_fn, cfg)
    assert res.final_step == 20
    assert res.resumed_from is None
    assert latest_step(str(tmp_path)) == 20


def test_crash_and_exact_resume(tmp_path):
    """Kill at step 13, resume, finish — final state equals the uninterrupted
    run exactly (pipeline is a pure function of step)."""
    train_step, init_state, batch_fn = _toy_setup()
    cfg = LoopConfig(total_steps=20, ckpt_every=5, ckpt_dir=str(tmp_path),
                     log_every=100)

    class Boom(RuntimeError):
        pass

    def bomb(step):
        if step == 13:
            raise Boom()

    with pytest.raises(Boom):
        run_training(train_step, init_state, batch_fn, cfg, step_hook=bomb)
    # crash-path checkpoint wrote step 13
    assert latest_step(str(tmp_path)) == 13

    res = run_training(train_step, init_state, batch_fn, cfg)
    assert res.resumed_from == 13
    assert res.final_step == 20

    # ground truth: sum of 0..19
    expected = sum(float(s) for s in range(20))
    from repro.checkpoint.checkpoint import restore

    final, _, _ = restore(str(tmp_path), init_state)
    assert float(final["x"]) == expected


def test_resume_loses_at_most_ckpt_every(tmp_path):
    train_step, init_state, batch_fn = _toy_setup()
    cfg = LoopConfig(total_steps=50, ckpt_every=10, ckpt_dir=str(tmp_path),
                     log_every=100)

    def bomb(step):
        if step == 37:
            raise KeyboardInterrupt()   # preemption signal path

    with pytest.raises(KeyboardInterrupt):
        run_training(train_step, init_state, batch_fn, cfg, step_hook=bomb)
    assert latest_step(str(tmp_path)) == 37    # best-effort crash checkpoint


def test_straggler_watchdog_triggers_elastic_restart(tmp_path):
    """Inject persistent 10x step latency after warmup -> ElasticRestart with
    a checkpoint, the signal the launcher uses to remap the mesh."""
    train_step, init_state, batch_fn = _toy_setup()
    cfg = LoopConfig(total_steps=1000, ckpt_every=1000, ckpt_dir=str(tmp_path),
                     log_every=1000, slow_factor=3.0, max_consecutive_slow=4,
                     watchdog_warmup=10)

    clock = {"t": 0.0}
    slow_from = 30

    def time_fn():
        return clock["t"]

    def hook(step):
        clock["t"] += 1.0 if step < slow_from else 10.0

    with pytest.raises(ElasticRestart):
        run_training(train_step, init_state, batch_fn, cfg, step_hook=hook,
                     time_fn=time_fn)
    assert latest_step(str(tmp_path)) is not None   # checkpointed before raise


def test_transient_blip_does_not_restart(tmp_path):
    """A single slow step (GC pause, retried DMA) must not trigger a restart."""
    train_step, init_state, batch_fn = _toy_setup()
    cfg = LoopConfig(total_steps=60, ckpt_every=100, ckpt_dir=str(tmp_path),
                     log_every=100, slow_factor=3.0, max_consecutive_slow=4,
                     watchdog_warmup=10)
    clock = {"t": 0.0}

    def time_fn():
        return clock["t"]

    def hook(step):
        clock["t"] += 20.0 if step == 30 else 1.0   # one blip

    res = run_training(train_step, init_state, batch_fn, cfg, step_hook=hook,
                       time_fn=time_fn)
    assert res.final_step == 60
    assert res.straggler_events == 1


def test_real_model_resume_bitexact(tmp_path):
    """Integration: reduced llama3 trains 6 steps, crashes, resumes, and the
    final params match an uninterrupted 6-step run bit-for-bit."""
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticTokens
    from repro.models.model import Model
    from repro.optim.adamw import make_optimizer
    from repro.train.steps import TrainState, make_train_step

    cfg = get_config("llama3-8b").reduced()
    model = Model(cfg)
    opt = make_optimizer(base_lr=1e-3, warmup=1, total=10)
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=16)
    step_fn = jax.jit(make_train_step(model, opt))

    def batch_fn(step):
        b = data.batch(step, 2)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def fresh_state():
        params = model.init(jax.random.PRNGKey(0))
        return TrainState(params=params, opt=opt.init(params))

    # uninterrupted
    s = fresh_state()
    for t in range(6):
        s, _ = step_fn(s, batch_fn(t))
    ref = s

    # interrupted at 4 (ckpt_every=2 -> checkpoint at 4), resumed
    lcfg = LoopConfig(total_steps=6, ckpt_every=2, ckpt_dir=str(tmp_path),
                      log_every=100)

    def bomb(step):
        if step == 4:
            raise RuntimeError("preempted")

    with pytest.raises(RuntimeError):
        run_training(step_fn, fresh_state(), batch_fn, lcfg, step_hook=bomb)
    res = run_training(step_fn, fresh_state(), batch_fn, lcfg)
    assert res.resumed_from == 4 and res.final_step == 6

    from repro.checkpoint.checkpoint import restore

    final, _, _ = restore(str(tmp_path), fresh_state())
    for a, b in zip(jax.tree.leaves(final.params), jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
