"""FIFO-model autotuner: determinism, cache round-trip, config plumbing.

The autotuner must be a *function* of the schedule and the probe results:
given a fixed (fake) probe clock the whole search is deterministic, the
JSON cache round-trips to an identical config, and applying a config
replaces the executor's magic constants (micro-batch 16, planner block_h)
without perturbing a single output integer.
"""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.qir import export_qcnn, export_qmlp
from repro.deploy import FusedConvThresholdStage, compile_graph
from repro.deploy.autotune import (
    CONFIG_VERSION,
    TunedConfig,
    VMEM_BUDGET_BYTES,
    autotune_enabled,
    autotune_mode,
    autotune_model,
    block_h_candidates,
    config_path,
    load_config,
    plan_block_h,
    plan_block_mn,
    save_config,
    schedule_key,
    slo_micro_batch,
)
from repro.models.tiny import ICModel, KWSMLP

IN_SCALE = 1.0 / 127.0


def _mlp_compiled(width=16):
    model = KWSMLP(width=width)
    params = model.init(jax.random.PRNGKey(0))
    hidden_defs, _ = model.layers()
    graph = export_qmlp(hidden_defs, params["hidden"], params["head"],
                        meta={"model": "KWS"}, freeze_scales=True,
                        in_scale=IN_SCALE)
    return compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)


def _conv_compiled():
    rng = np.random.default_rng(5)
    model = ICModel(in_hw=8, filters=(4, 4), kernels=(3, 3), strides=(1, 2))
    params = model.init(jax.random.PRNGKey(5))
    cal = rng.integers(-127, 128, (4, 8, 8, 3)).astype(np.int32)
    graph = export_qcnn(model, params, calibrate=cal)
    return compile_graph(graph, in_scale=graph.meta["in_scale"],
                         use_pallas=False)


def _fixed_probe(times):
    """Deterministic probe clock: scripted seconds per micro-batch size."""
    def probe(cm, x, micro_batch):
        return times[micro_batch]
    return probe


def test_autotuner_is_deterministic_under_fixed_probe(tmp_path):
    cm = _mlp_compiled()
    probe = _fixed_probe({mb: 0.010 + 0.001 * mb for mb in (1, 2, 4, 8, 16,
                                                            32, 64)})
    a = autotune_model(cm, batch=32, probe=probe, directory=str(tmp_path),
                       force=True)
    b = autotune_model(cm, batch=32, probe=probe,
                       directory=str(tmp_path / "other"), force=True)
    assert a == b
    # fixed probe: monotone-increasing time in mb -> smallest probed wins
    assert str(a.micro_batch) in a.probe_ms
    assert a.probe_ms[str(a.micro_batch)] == min(a.probe_ms.values())
    # every candidate carries the modeled FIFO numbers that ranked it
    assert all("modeled_cycles" in c and "fifo_depths" in c
               for c in a.candidates)


def test_autotune_cache_round_trip_is_identical(tmp_path):
    cm = _conv_compiled()
    probe = _fixed_probe({mb: 0.005 for mb in (1, 2, 4, 8, 16, 32, 64)})
    cfg = autotune_model(cm, batch=16, probe=probe,
                         directory=str(tmp_path), force=True)
    # write -> load -> identical plan (the CI round-trip check)
    loaded = load_config(cfg.key, str(tmp_path))
    assert loaded == cfg
    # a second save of the loaded config is byte-stable
    p1 = config_path(cfg.key, str(tmp_path))
    with open(p1) as f:
        first = f.read()
    save_config(loaded, str(tmp_path))
    with open(p1) as f:
        assert f.read() == first
    # second autotune call hits the cache, no probe needed
    again = autotune_model(cm, batch=16, probe=None,
                           directory=str(tmp_path), force=False)
    assert again == cfg


def test_config_dict_round_trip():
    cfg = TunedConfig(key="k", platform="cpu", micro_batch=8,
                      block_h={"conv0": 4}, fifo_depths=[2, 2, 3],
                      modeled_cycles=123, modeled_traffic_bytes=456.5,
                      candidates=[{"micro_batch": 8, "modeled_cycles": 123}],
                      block_mn={"dense0": [256, 128]},
                      probe_ms={"8": 1.25})
    assert TunedConfig.from_dict(cfg.to_dict()) == cfg
    # unknown keys from future schemas are dropped, not fatal
    d = cfg.to_dict()
    d["new_field"] = "x"
    assert TunedConfig.from_dict(d) == cfg


def test_stale_config_version_re_searches(tmp_path):
    """A cached config from an older schema (no dense blocks) must be
    ignored, not half-applied."""
    cfg = TunedConfig(key="stale", platform="cpu", micro_batch=8,
                      block_h={}, fifo_depths=[2, 2],
                      modeled_cycles=1, modeled_traffic_bytes=1.0)
    cfg.version = CONFIG_VERSION - 1
    save_config(cfg, str(tmp_path))
    assert load_config("stale", str(tmp_path)) is None


def test_apply_tuned_replaces_magic_constants_bit_exactly(tmp_path):
    cm = _conv_compiled()
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.integers(-127, 128, (6, 8, 8, 3)), jnp.int32)
    y_before = np.asarray(cm.offline(x))
    assert cm.default_micro_batch == 16    # the historical constant
    probe = _fixed_probe({mb: 0.005 for mb in (1, 2, 4, 8, 16, 32, 64)})
    cfg = autotune_model(cm, batch=16, probe=probe, force=True,
                         directory=str(tmp_path))
    cm.apply_tuned(cfg)
    assert cm.default_micro_batch == cfg.micro_batch
    conv_stages = [s for s in cm.schedule.stages
                   if isinstance(s, FusedConvThresholdStage)]
    assert conv_stages and all(s.block_h == cfg.block_h[s.name]
                               for s in conv_stages)
    assert all(1 <= s.block_h <= s.geom.out_h for s in conv_stages)
    # tuning changes schedules' execution parameters, never the integers
    np.testing.assert_array_equal(np.asarray(cm.offline(x)), y_before)
    y_s, st = cm.streaming_compiled(x)
    assert st.micro_batch == cfg.micro_batch
    np.testing.assert_allclose(np.asarray(y_s), y_before,
                               rtol=1e-6, atol=1e-6)


def test_plan_block_h_respects_vmem_and_breaks_ties_to_target():
    from repro.deploy import ConvGeom

    # no halo (K=1): every block size streams equal bytes; the tie-break
    # lands near the 256-row matmul target, not at 1
    g1 = ConvGeom(kernel=1, stride=1, padding="SAME", in_h=32, in_w=32,
                  in_ch=3, out_h=32, out_w=32, out_ch=8)
    plan = plan_block_h(g1)
    assert plan["block_h"] == 8            # 8 * 32 = 256 rows
    traffics = {c["input_bytes"] for c in plan["candidates"]}
    assert len(traffics) == 1
    # halo case (K=3, stride 1): traffic strictly decreases with block_h,
    # so the biggest fitting block wins
    g2 = ConvGeom(kernel=3, stride=1, padding="SAME", in_h=32, in_w=32,
                  in_ch=8, out_h=32, out_w=32, out_ch=8)
    assert plan_block_h(g2)["block_h"] == 32
    # a tiny VMEM budget forces small blocks
    small = plan_block_h(g2, budget_bytes=1 << 12)["block_h"]
    assert small < 32
    cands = plan_block_h(g2)["candidates"]
    assert [c["block_h"] for c in cands] == block_h_candidates(32)


def test_plan_block_mn_respects_vmem_and_breaks_ties_to_mxu():
    """The dense-block model: streamed bytes fall as blocks grow, VMEM
    caps the growth, and byte ties break toward the 128x128 MXU tile."""
    plan = plan_block_mn(490, 128, n_steps=7)
    assert plan["block_n"] == 128          # out_dim 128: one column block
    assert plan["block_m"] >= 128          # bigger bm cuts w/threshold bytes
    fits = [c for c in plan["candidates"] if c["fits_vmem"]]
    assert plan["stream_bytes"] == min(c["stream_bytes"] for c in fits)
    # a tiny budget forces small blocks; an impossible one falls back
    small = plan_block_mn(490, 128, n_steps=7, budget_bytes=1 << 14)
    assert (small["block_m"], small["block_n"]) < (plan["block_m"], 512)
    assert all(not c["fits_vmem"]
               for c in plan_block_mn(4096, 4096, n_steps=255,
                                      budget_bytes=1 << 10)["candidates"])
    # the w/threshold byte terms strictly fall with block_m at fixed bn
    rows = {(c["block_m"], c["block_n"]): c["stream_bytes"]
            for c in plan["candidates"]}
    assert rows[(256, 128)] < rows[(32, 128)]


def test_autotune_tunes_dense_blocks_bit_exactly(tmp_path):
    """v2 configs carry block_mn for every fused dense stage; applying
    them reconfigures the kernel blocks without changing any integers
    (including through the Pallas interpret path that consumes them)."""
    cm = _mlp_compiled()
    probe = _fixed_probe({mb: 0.005 for mb in (1, 2, 4, 8, 16, 32, 64)})
    cfg = autotune_model(cm, batch=16, probe=probe,
                         directory=str(tmp_path), force=True)
    dense = [s for s in cm.schedule.stages
             if type(s).__name__ == "FusedThresholdStage"]
    assert dense and set(cfg.block_mn) == {s.name for s in dense}
    assert all(name in cfg.block_mn_model for name in cfg.block_mn)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(-127, 128, (5, 490)), jnp.int32)
    y_before = np.asarray(cm.offline(x))
    cm.apply_tuned(cfg)
    assert all([s.block_m, s.block_n] == cfg.block_mn[s.name]
               for s in dense)
    np.testing.assert_array_equal(np.asarray(cm.offline(x)), y_before)
    # kernel path (interpret mode) consumes the tuned blocks, same integers
    cmk = compile_graph(cm.graph, in_scale=IN_SCALE, use_pallas=True,
                        interpret=True)
    cmk.apply_tuned(cfg)
    np.testing.assert_array_equal(np.asarray(cmk.offline(x)), y_before)
    # the cache round-trips the new fields exactly
    assert load_config(cfg.key, str(tmp_path)) == cfg


def test_slo_micro_batch_grows_with_the_budget():
    """The SLO-constrained objective: a bigger latency budget admits a
    wave at least as large, and the chosen wave's modeled service fits."""
    cm = _mlp_compiled()
    pts = [slo_micro_batch(cm, b) for b in (0.001, 5.0, 5000.0)]
    mbs = [p["micro_batch"] for p in pts]
    assert mbs == sorted(mbs)
    assert pts[-1]["micro_batch"] == 64      # huge budget: biggest candidate
    assert pts[-1]["fits_budget"]
    assert pts[-1]["service_ms"] <= 5000.0
    assert pts[-1]["calibration"]["probe_batch"] == 8
    for p in pts:
        assert [c["micro_batch"] for c in p["candidates"]] == \
            sorted(c["micro_batch"] for c in p["candidates"])


def test_autotune_mode_tri_state_parsing(monkeypatch):
    """Every documented spelling resolves to its mode; unknown spellings
    are a hard error (a typo must never silently fall back to probing)."""
    cases = {
        "off": ("off", "0", "", "false", "no", "none", "disable",
                "disabled", "OFF", " Off "),
        "probe": ("probe", "1", "on", "true", "yes", "probed", "measure"),
        "model": ("model", "predict", "predicted", "predictor", "MODEL"),
    }
    for want, spellings in cases.items():
        for raw in spellings:
            monkeypatch.setenv("REPRO_AUTOTUNE", raw)
            assert autotune_mode() == want, raw
            assert autotune_enabled() == (want != "off")
    monkeypatch.delenv("REPRO_AUTOTUNE")
    assert autotune_mode() == "probe"        # the historical default
    for bad in ("modle", "2", "maybe", "model "):
        monkeypatch.setenv("REPRO_AUTOTUNE", bad.upper() + "x")
        with pytest.raises(ValueError, match="REPRO_AUTOTUNE"):
            autotune_mode()
    # the error propagates through the compile_graph gate too
    monkeypatch.setenv("REPRO_AUTOTUNE", "modle")
    with pytest.raises(ValueError, match="off|probe|model"):
        autotune_enabled()


def test_compile_graph_autotune_flag_and_env_knobs(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    cm = _mlp_compiled()
    graph = cm.graph
    # REPRO_AUTOTUNE=0 disables the whole thing
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert not autotune_enabled()
    cm0 = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False,
                        autotune=True)
    assert cm0.tuned is None
    monkeypatch.delenv("REPRO_AUTOTUNE")
    assert autotune_enabled()
    # enabled: searches (wall probes on this tiny model), caches, applies
    cm1 = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False,
                        autotune=True)
    assert cm1.tuned is not None
    assert os.path.exists(config_path(schedule_key(cm1), str(tmp_path)))
    # a second compile consumes the cache (config equality, no re-search)
    cm2 = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False,
                        autotune=True)
    assert cm2.tuned == cm1.tuned
    # prebuilt configs can be passed straight through
    cm3 = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False,
                        tuned=cm1.tuned)
    assert cm3.tuned == cm1.tuned


def test_autotune_segment_mode_persisted_and_bit_exact(tmp_path):
    """The config carries the megakernel/staged dispatch choice: on the
    MLP the residency planner admits a fused run, deterministic probes
    tie, and the traffic model breaks the tie toward the megakernel (it
    can only save bytes). Applying the config flips the executor's
    dispatch without changing any integers."""
    cm = _mlp_compiled()
    probe = _fixed_probe({mb: 0.005 for mb in (1, 2, 4, 8, 16, 32, 64)})
    cfg = autotune_model(cm, batch=16, probe=probe,
                         directory=str(tmp_path), force=True)
    assert cfg.version == CONFIG_VERSION == 4
    assert cfg.source == "probed"
    # v4's measured block_mn refinement ran at the winning wave size and
    # its probe pair landed in the audit trail (ties keep the model pick)
    assert cfg.block_mn_probe["pick"] == "tuned"
    assert cfg.block_mn_probe["wave_rows"] == cfg.micro_batch
    assert set(cfg.block_mn_probe["probe_ms"]) == {"tuned", "default"}
    assert cfg.segment_mode == "megakernel"
    m = cfg.segment_mode_model
    assert m["plans"] and m["model_pick"] == "megakernel"
    assert m["megakernel_bytes"] < m["staged_bytes"]
    assert m["bytes_saved"] == m["staged_bytes"] - m["megakernel_bytes"]
    assert m["probe_ms"]["megakernel"] == m["probe_ms"]["staged"]
    assert cm.megakernel is None     # probing restored the pre-search mode
    assert load_config(cfg.key, str(tmp_path)) == cfg
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(-127, 128, (6, 490)), jnp.int32)
    y_auto = np.asarray(cm.offline(x))
    cm.apply_tuned(cfg)
    assert cm.megakernel is True and cm._mega_plans
    np.testing.assert_array_equal(np.asarray(cm.offline(x)), y_auto)
    # the explicit staged path agrees bit for bit (the reference)
    cm.set_megakernel(False)
    np.testing.assert_array_equal(np.asarray(cm.offline(x)), y_auto)


def test_autotune_segment_mode_staged_when_planner_admits_nothing(tmp_path):
    """The conv model has no fused dense run, so the choice degrades to
    staged with an empty model record — and applying it is a no-op for
    dispatch."""
    cm = _conv_compiled()
    probe = _fixed_probe({mb: 0.005 for mb in (1, 2, 4, 8, 16, 32, 64)})
    cfg = autotune_model(cm, batch=16, probe=probe,
                         directory=str(tmp_path), force=True)
    assert cfg.segment_mode == "staged"
    assert cfg.segment_mode_model == {}
    cm.apply_tuned(cfg)
    assert cm.megakernel is False and cm._mega_plans == {}


def test_schedule_key_distinguishes_models():
    k1 = schedule_key(_mlp_compiled())
    k2 = schedule_key(_conv_compiled())
    assert k1 != k2
    assert k1 == schedule_key(_mlp_compiled())   # stable
