"""Learned wave-cost predictor: feature schema, dataset determinism,
probe-free autotuning, and predictor-priced cold-start admission.

The contract under test is ROADMAP direction 5's loop: deterministic
features from static structure -> byte-reproducible training table ->
seedable predictor artifact -> zero-probe ``REPRO_AUTOTUNE=model`` configs
that are bit-exact at execution -> a ``PredictedServiceModel`` that prices
admission for a model the server has never measured, as an exact
discrete-event simulation under ``ManualClock``.
"""

import dataclasses
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.qir import export_qmlp
from repro.costmodel import (
    FEATURE_NAMES,
    FEATURE_SCHEMA_VERSION,
    Dataset,
    WaveCostPredictor,
    bootstrap_rows,
    build_dataset,
    compiled_feature_resolver,
    feature_vector,
    features_from_model_cost,
    leave_one_model_out,
    load_trace_records,
    rows_from_tuned_config,
    wave_features,
)
from repro.deploy import compile_graph
from repro.deploy.autotune import (
    CONFIG_VERSION,
    TunedConfig,
    autotune_model,
    load_config,
    save_config,
)
from repro.models.tiny import KWSMLP
from repro.serve import (
    AsyncEngine,
    ManualClock,
    PredictedServiceModel,
    Router,
    RouterConfig,
    SLOController,
    poisson_trace,
)
from repro.serve.sim import scripted_pool

IN_SCALE = 1.0 / 127.0


def _mlp_compiled(width=16):
    model = KWSMLP(width=width)
    params = model.init(jax.random.PRNGKey(0))
    hidden_defs, _ = model.layers()
    graph = export_qmlp(hidden_defs, params["hidden"], params["head"],
                        meta={"model": "KWS"}, freeze_scales=True,
                        in_scale=IN_SCALE)
    return compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)


# ---------------------------------------------------------------------------
# features: versioned schema, pure function of structure
# ---------------------------------------------------------------------------

def test_wave_features_schema_and_determinism():
    cm = _mlp_compiled()
    a = wave_features(cm, 16)
    b = wave_features(cm, 16)
    assert a == b                          # pure arithmetic, no clocks/RNG
    assert tuple(a) == FEATURE_NAMES       # exact schema, exact order
    v = feature_vector(a)
    assert v.shape == (len(FEATURE_NAMES),)
    assert np.all(np.isfinite(v))
    # wave size is a real input, not a constant column
    assert wave_features(cm, 64) != a
    # a missing feature is a KeyError, never a silent zero
    broken = dict(a)
    del broken["log_wave_cycles"]
    with pytest.raises(KeyError):
        feature_vector(broken)


def test_wave_features_segment_mode_independent_of_model_state():
    """Scoring "megakernel" vs "staged" must not depend on (or mutate) the
    dispatch mode the executor object currently happens to be in — that is
    what lets model-mode autotune rank both flavors probe-free."""
    cm = _mlp_compiled()
    mega = wave_features(cm, 16, "megakernel")
    staged = wave_features(cm, 16, "staged")
    assert staged["log_residency_bytes"] == 0.0
    assert staged["megakernel"] == 0.0
    assert mega["megakernel"] == 1.0
    assert mega["log_residency_bytes"] > 0.0
    # the fused wave streams fewer bytes — the traffic model's whole point
    assert mega["log_wave_traffic_bytes"] < staged["log_wave_traffic_bytes"]
    cm.set_megakernel(False)
    assert wave_features(cm, 16, "megakernel") == mega
    assert wave_features(cm, 16) == staged     # None follows current mode
    cm.set_megakernel(True)
    assert wave_features(cm, 16, "staged") == staged


def test_features_from_model_cost_covers_schema():
    from repro.core.bops import ModelCost, dense_cost

    mc = ModelCost([dense_cost("d0", 490, 128), dense_cost("d1", 128, 10)])
    feats = features_from_model_cost(mc, 8)
    assert tuple(feats) == FEATURE_NAMES
    assert feats["n_stages"] == 2.0
    assert np.all(np.isfinite(feature_vector(feats)))


# ---------------------------------------------------------------------------
# dataset: byte-identical determinism
# ---------------------------------------------------------------------------

def _fake_trace_records(n=6):
    return [{"model": "KWS", "platform": "cpu", "micro_batch": 4 * (i % 3 + 1),
             "n_valid": 4, "predicted_ms": 1.0 + 0.1 * i,
             "measured_ms": 1.2 + 0.1 * i} for i in range(n)]


def test_dataset_builder_is_byte_identical_under_input_order(tmp_path):
    cm = _mlp_compiled()
    resolver = compiled_feature_resolver({"KWS": cm})
    records = _fake_trace_records()
    a = build_dataset(resolver, trace_records=records)
    b = build_dataset(resolver, trace_records=list(reversed(records)))
    assert a.to_json_str() == b.to_json_str()
    p1 = a.save(str(tmp_path / "a.json"))
    p2 = b.save(str(tmp_path / "b.json"))
    assert open(p1, "rb").read() == open(p2, "rb").read()
    # load -> save round-trips byte-identically too
    assert Dataset.load(p1).to_json_str() == a.to_json_str()
    # rows name the analytic baseline column from the trace
    assert all(r["analytic_ms"] is not None and r["source"] == "trace"
               for r in a.rows)
    # unknown models are skipped by the resolver, not crashed on
    ghost = dict(records[0], model="never-compiled")
    assert build_dataset(resolver,
                         trace_records=[ghost]).rows == []


def test_dataset_load_rejects_foreign_schema(tmp_path):
    cm = _mlp_compiled()
    ds = build_dataset(compiled_feature_resolver({"KWS": cm}),
                       trace_records=_fake_trace_records(2))
    path = ds.save(str(tmp_path / "ds.json"))
    doc = json.loads(open(path).read())
    doc["feature_schema_version"] = FEATURE_SCHEMA_VERSION + 1
    open(path, "w").write(json.dumps(doc))
    with pytest.raises(ValueError, match="schema"):
        Dataset.load(path)


def test_trace_jsonl_round_trip(tmp_path):
    """The JSONL shard path: export -> load -> identical dataset bytes."""
    from repro.obs import Tracer, export_prediction_records

    tracer = Tracer()
    for i, r in enumerate(_fake_trace_records(4)):
        t0 = 0.01 * i
        tracer.add_span("wave", t0, t0 + r["measured_ms"] / 1e3, cat="serve",
                        args={"model": r["model"], "platform": r["platform"],
                              "micro_batch": r["micro_batch"],
                              "n_valid": r["n_valid"],
                              "predicted_ms": r["predicted_ms"]})
    path = export_prediction_records(tracer, str(tmp_path / "t.jsonl"))
    cm = _mlp_compiled()
    resolver = compiled_feature_resolver({"KWS": cm})
    direct = build_dataset(resolver, trace_records=_fake_trace_records(4))
    via_disk = build_dataset(resolver,
                             trace_records=load_trace_records(path))
    # measured_ms goes through the span clock; compare rows field-by-field
    assert len(via_disk.rows) == len(direct.rows) == 4
    for a, b in zip(via_disk.rows, direct.rows):
        assert a["features"] == b["features"]
        assert a["micro_batch"] == b["micro_batch"]
        assert a["measured_ms"] == pytest.approx(b["measured_ms"])


def test_rows_from_tuned_config_harvests_every_probe(tmp_path):
    """Probe-mode audit trails become per-wave labeled rows: micro-batch
    candidates, the segment-mode probe pair, and the block_mn probe pair;
    model-mode configs contribute no measured rows."""
    cm = _mlp_compiled()
    probe = lambda c, x, mb: 0.004 + 0.0001 * mb
    cfg = autotune_model(cm, batch=16, probe=probe,
                         directory=str(tmp_path), force=True)
    resolver = compiled_feature_resolver({"KWS": cm})
    rows = rows_from_tuned_config(cfg, resolver)
    sources = {r["source"] for r in rows}
    assert sources == {"autotune"}
    probed_mbs = {r["micro_batch"] for r in rows}
    assert int(cfg.micro_batch) in probed_mbs
    seg_modes = {r["segment_mode"] for r in rows}
    assert {"megakernel", "staged"} <= seg_modes  # the probe pair
    # per-wave normalization: candidate probe_ms spans n_micro waves
    cand = next(c for c in cfg.candidates
                if c["micro_batch"] == cfg.micro_batch)
    per_wave = cand["probe_ms"] / cand["n_micro"]
    assert any(r["measured_ms"] == pytest.approx(per_wave) for r in rows)
    # model-mode config: predictions are not measurements
    predictor = WaveCostPredictor.fit_rows(bootstrap_rows(), l2=1.0, seed=0,
                                           n_members=2)
    mcfg = autotune_model(cm, batch=16, mode="model", predictor=predictor,
                          directory=str(tmp_path / "m"), force=True)
    assert rows_from_tuned_config(mcfg, resolver) == []


# ---------------------------------------------------------------------------
# predictor: seedable fit, artifact round-trip, LOMO
# ---------------------------------------------------------------------------

def test_predictor_fit_is_seed_deterministic_and_round_trips(tmp_path):
    rows = bootstrap_rows()
    a = WaveCostPredictor.fit_rows(rows, l2=1e-2, seed=7, n_members=4)
    b = WaveCostPredictor.fit_rows(rows, l2=1e-2, seed=7, n_members=4)
    np.testing.assert_array_equal(a.weights, b.weights)
    c = WaveCostPredictor.fit_rows(rows, l2=1e-2, seed=8, n_members=4)
    assert not np.array_equal(a.weights, c.weights)   # seed is real
    feats = rows[0]["features"]
    p = a.predict_ms(feats)
    assert np.isfinite(p) and p > 0
    path = a.save(str(tmp_path / "m.json"))
    loaded = WaveCostPredictor.load(path)
    assert loaded.predict_ms(feats) == p
    # matrix scoring agrees with scalar scoring
    X = np.stack([feature_vector(r["features"]) for r in rows[:5]])
    np.testing.assert_allclose(
        a.predict_ms(X), [a.predict_ms(r["features"]) for r in rows[:5]])


def test_predictor_artifact_rejects_schema_drift(tmp_path):
    pred = WaveCostPredictor.fit_rows(bootstrap_rows(), n_members=2)
    d = pred.to_dict()
    d["schema_version"] = FEATURE_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema"):
        WaveCostPredictor.from_dict(d)
    d = pred.to_dict()
    d["feature_names"] = list(reversed(d["feature_names"]))
    with pytest.raises(ValueError, match="feature names"):
        WaveCostPredictor.from_dict(d)


def test_shipped_default_artifact_loads_and_scores():
    from repro.costmodel import load_default

    pred = load_default()
    assert pred.schema_version == FEATURE_SCHEMA_VERSION
    assert tuple(pred.feature_names) == FEATURE_NAMES
    cm = _mlp_compiled()
    p = pred.predict_ms(wave_features(cm, 16))
    assert np.isfinite(p) and p > 0


def test_leave_one_model_out_holds_out_whole_families():
    rows = bootstrap_rows()
    out = leave_one_model_out(rows, l2=1e-2, seed=0, n_members=4)
    families = sorted({r["model"] for r in rows})
    assert sorted(k for k in out if k != "overall") == families
    assert out["overall"]["n"] == len(rows)
    for fam in families:
        assert out[fam]["n"] == sum(r["model"] == fam for r in rows)
        assert np.isfinite(out[fam]["median_abs_rel_err"])
    # generalizes across the synthetic fleet: held-out error is bounded
    assert out["overall"]["median_abs_rel_err"] < 0.5


# ---------------------------------------------------------------------------
# probe-free autotuning
# ---------------------------------------------------------------------------

def _probe_bomb(*a, **k):
    raise AssertionError("model mode must never run a measured probe")


def test_autotune_model_mode_runs_zero_probes_and_is_bit_exact(tmp_path):
    cm = _mlp_compiled()
    predictor = WaveCostPredictor.fit_rows(bootstrap_rows(), l2=1e-2,
                                           seed=0, n_members=4)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-127, 128, (6, 490)), jnp.int32)
    y_before = np.asarray(cm.offline(x))
    cfg = autotune_model(cm, batch=32, mode="model", predictor=predictor,
                         probe=_probe_bomb, directory=str(tmp_path),
                         force=True)
    # a full config, zero wall-clock reads
    assert cfg.source == "predicted"
    assert cfg.version == CONFIG_VERSION
    assert cfg.probe_ms is None and cfg.seed_stage_ms is None
    assert cfg.block_mn_probe == {}
    assert cfg.micro_batch >= 1 and cfg.block_h is not None
    assert cfg.block_mn            # dense blocks still planned (pure model)
    # every candidate was priced by the predictor, none probed
    assert all("predicted_wave_ms" in c and "probe_ms" not in c
               for c in cfg.candidates)
    assert cfg.segment_mode_model["predicted_ms"].keys() == {
        "megakernel", "staged"}
    # deterministic: same model + same predictor -> identical config
    again = autotune_model(cm, batch=32, mode="model", predictor=predictor,
                           probe=_probe_bomb,
                           directory=str(tmp_path / "b"), force=True)
    assert again == cfg
    # the cache round-trips the provenance
    assert load_config(cfg.key, str(tmp_path)) == cfg
    # applying the predicted config never changes an output integer
    cm.apply_tuned(cfg)
    assert cm.default_micro_batch == cfg.micro_batch
    np.testing.assert_array_equal(np.asarray(cm.offline(x)), y_before)
    y_s, st = cm.streaming_compiled(x)
    assert st.micro_batch == cfg.micro_batch
    np.testing.assert_allclose(np.asarray(y_s), y_before,
                               rtol=1e-6, atol=1e-6)


def test_v3_cache_migrates_by_re_search_and_v4_round_trips(tmp_path):
    """A v3 cache file (no provenance, no block_mn probe trail) must be
    ignored — never half-applied with default-filled fields — while v4
    configs round-trip ``source`` exactly."""
    v3 = TunedConfig(key="old", platform="cpu", micro_batch=8,
                     block_h={}, fifo_depths=[2, 2], modeled_cycles=9,
                     modeled_traffic_bytes=1.0)
    d = v3.to_dict()
    d["version"] = 3
    del d["source"], d["block_mn_probe"]     # what a real v3 file lacks
    (tmp_path / "old.json").write_text(json.dumps(d))
    assert load_config("old", str(tmp_path)) is None
    # v4 round-trip keeps provenance through dict/json/dataclass layers
    v4 = dataclasses.replace(v3, key="new", source="predicted",
                             block_mn_probe={"pick": "tuned"})
    save_config(v4, str(tmp_path))
    loaded = load_config("new", str(tmp_path))
    assert loaded == v4 and loaded.source == "predicted"
    assert TunedConfig.from_dict(v4.to_dict()) == v4


def test_autotune_model_mode_rejects_unknown_mode():
    cm = _mlp_compiled()
    with pytest.raises(ValueError, match="probe|model"):
        autotune_model(cm, mode="banana", force=True)


# ---------------------------------------------------------------------------
# cold-start admission: exact discrete-event simulation
# ---------------------------------------------------------------------------

def _predicted_service(mb=4, predicted_s=0.004):
    # a real per-sample work term so off-table extrapolation has a shape
    return PredictedServiceModel.from_table([("s", 4096)],
                                            {mb: predicted_s})


def test_predicted_service_model_prices_before_any_measurement():
    """A finite, sane admission estimate exists before the server has ever
    completed (or even submitted) a wave — the whole cold-start point."""
    service = _predicted_service(mb=4, predicted_s=0.004)
    assert service.wave_service_s(4) == pytest.approx(0.004)
    # off-table sizes extrapolate along the FIFO shape, monotonically
    assert service.wave_service_s(8) > service.wave_service_s(4)
    assert service.wave_service_s(1) < service.wave_service_s(4)
    ctl = SLOController(p99_budget_ms=20.0, service=service)
    est = ctl.estimated_latency_s(backlog_waves=2, micro_batch=4,
                                  max_wait_s=0.002)
    assert np.isfinite(est) and est == pytest.approx(0.002 + 3 * 0.004)
    assert ctl.admit(0.0, 2, 4, 0.002)
    assert not ctl.admit(0.0, 10, 4, 0.002)   # priced shedding, wave 0
    # the first measured wave starts correcting the prediction online
    ctl.observe_service(4, 0.008)
    assert ctl.wave_service_s(4) > 0.004


def test_predicted_service_model_recalibrates_toward_measured():
    service = _predicted_service(mb=4, predicted_s=0.004)
    fixed = service.recalibrated(0.006, 4)
    assert fixed.wave_service_s(4) == pytest.approx(0.006)
    assert fixed.calibration["dispatch_overhead_ratio"] == pytest.approx(1.5)
    # off-table extrapolation scales with the same correction
    assert fixed.wave_service_s(8) == pytest.approx(
        service.wave_service_s(8) * 1.5)


def _cold_start_sim(priced: bool):
    clock = ManualClock()
    mb, true_s = 4, 0.004
    pool = scripted_pool(clock, [true_s], micro_batch=mb)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=2.0, micro_batch=mb,
                     p99_budget_ms=14.0 if priced else None),
        clock=clock,
        service_models={"m": _predicted_service(mb, 0.0035)} if priced
        else None,
        engine=AsyncEngine())
    trace = poisson_trace(qps=2.5 * mb / true_s, n=160, seed=5)
    reqs = router.run_trace(
        "m", trace, lambda i: np.full((2,), i % 100, np.int32))
    served = [r for r in reqs if not r.shed]
    lats = np.asarray([r.latency_s for r in served]) * 1e3
    return {"shed": [bool(r.shed) for r in reqs],
            "done_t": [r.done_t for r in served],
            "p99_ms": float(np.percentile(lats, 99))}


def test_cold_start_admission_is_priced_and_byte_reproducible():
    """Under 2.5x overload the predictor-priced run sheds from wave 0 and
    holds the p99 inside the budget; the unpriced status quo (no service
    model for an unmeasured model) queues everything and blows through it.
    Both are ManualClock discrete-event sims: re-running is bit-identical."""
    priced = _cold_start_sim(priced=True)
    unpriced = _cold_start_sim(priced=False)
    assert any(priced["shed"])            # admission control engaged early
    assert not any(unpriced["shed"])      # status quo: nothing sheds
    assert priced["p99_ms"] <= 14.0 < unpriced["p99_ms"]
    # exact reproducibility, field for field, no tolerance
    again = _cold_start_sim(priced=True)
    assert again == priced