"""Roofline analysis + launcher smoke tests (reads the real dry-run
artifacts when present; otherwise synthesizes a record)."""

import json
import os

import numpy as np
import pytest

from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_BF16,
    PEAK_INT8,
    RooflineRow,
    analyze,
    load_artifacts,
    render_table,
)


def _fake_record(**kw):
    rec = {
        "arch": "llama3-8b", "shape": "train_4k", "mesh": "single",
        "tag": "baseline", "quant_bits": 16, "status": "ok",
        "n_devices": 256,
        "hlo_flops_per_device": 1e15,
        "collective_bytes_per_device": 5e10,
        "xla_cost_analysis": {"flops": 1e15, "bytes_accessed": 2e14},
        "memory_analysis": {"output_size_in_bytes": 1e12},
        "state_local_bytes": 1e9, "cache_local_bytes": 0,
    }
    rec.update(kw)
    return rec


def test_roofline_terms_formulae():
    row = analyze(_fake_record())
    assert row.t_compute == pytest.approx(1e15 / PEAK_BF16)
    assert row.t_collective == pytest.approx(5e10 / ICI_BW)
    # memory term uses max(xla_bytes/dev, working set)
    assert row.t_memory >= (2e14 / 256) / HBM_BW
    assert row.dominant in ("compute", "memory", "collective")


def test_quantized_cell_uses_int8_peak():
    r16 = analyze(_fake_record())
    r8 = analyze(_fake_record(quant_bits=8))
    assert r8.t_compute == pytest.approx(r16.t_compute / 2)


def test_dominant_term_selection():
    row = analyze(_fake_record(collective_bytes_per_device=1e13))
    assert row.dominant == "collective"
    row = analyze(_fake_record(hlo_flops_per_device=1e17,
                               collective_bytes_per_device=0.0))
    assert row.dominant == "compute"


def test_skipped_cells_pass_through():
    row = analyze({"arch": "hubert-xlarge", "shape": "decode_32k",
                   "mesh": "single", "tag": "baseline", "status": "skipped",
                   "reason": "encoder-only"})
    assert row.status == "skipped"
    txt = render_table([row])
    assert "skipped" in txt


@pytest.mark.skipif(not os.path.isdir("artifacts/dryrun"),
                    reason="no dry-run artifacts")
def test_real_artifacts_sane():
    """Every ok cell: positive terms, useful ratio in (0, 1.5], and the
    full 40-cell assignment is present for both meshes."""
    rows = [analyze(r) for r in load_artifacts("artifacts/dryrun")]
    by_mesh = {}
    for r in rows:
        by_mesh.setdefault((r.mesh, r.tag), []).append(r)
    for mesh in ("single", "multi"):
        cells = by_mesh.get((mesh, "baseline"), [])
        assert len(cells) == 40, (mesh, len(cells))
        ok = [r for r in cells if r.status == "ok"]
        skipped = [r for r in cells if r.status == "skipped"]
        assert len(ok) == 32 and len(skipped) == 8
        for r in ok:
            assert r.t_compute > 0, (r.arch, r.shape)
            assert 0 < r.useful_ratio <= 1.5, (r.arch, r.shape, r.useful_ratio)


def test_strategy_rules_shapes():
    from repro.configs import get_config
    from repro.launch.dryrun import STRATEGIES, strategy_rules

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    cfg = get_config("llama3-8b")
    for s in STRATEGIES:
        rules = strategy_rules(s, cfg, FakeMesh(), None)
        assert isinstance(rules, dict)
    assert strategy_rules("fsdp2d", cfg, FakeMesh(), None)["batch"] == (
        "data", "model")
    assert strategy_rules("tponly", cfg, FakeMesh(), None)["fsdp"] is None
    with pytest.raises(ValueError):
        strategy_rules("nope", cfg, FakeMesh(), None)


def test_launch_train_main_smoke(tmp_path):
    from repro.launch.train import main

    res = main(["--arch", "internlm2-1.8b", "--reduced", "--steps", "6",
                "--batch", "2", "--seq", "16", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "3"])
    assert res.final_step == 6


def test_launch_serve_main_smoke():
    from repro.launch.serve import main

    stats = main(["--arch", "internlm2-1.8b", "--requests", "2",
                  "--max-new", "3", "--max-len", "32"])
    assert stats["n_requests"] == 2
