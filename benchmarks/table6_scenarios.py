"""Beyond-paper Table 6: MLPerf-Tiny load scenarios over compiled deployments.

The paper reports single-inference latency/energy (Table 5). MLPerf Tiny
actually scores submissions under LoadGen scenarios; this section runs the
full sweep — SingleStream / MultiStream / Offline / Server — for all four
Table-1 models through ``repro.deploy``:

  * KWS + AD lower through the real compiler path:
      QAT export -> QIR json -> streamline/fuse -> jit stage schedule,
    and their Offline rows compare the compiled executor against the unfused
    per-node QIR interpreter (the "no compiler" baseline it must beat).
  * IC + CNV (conv nets, no QIR export yet) deploy as whole-forward jit
    programs with the same scenario harness, so every Table-1 row is load-
    tested under one format.

Also prints the FIFO-sized streaming schedule for KWS (the §3.1.2 depths
feeding a real execution) and a multi-tenant section where all four models
share one ``TinyModelServer`` queue.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, print_rows, row
from repro.core.qir import export_qmlp
from repro.deploy import CompiledJaxModel, compile_graph
from repro.deploy.scenarios import offline, single_stream
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP
from repro.serving.engine import TinyModelServer

IN_SCALE = 1.0 / 127.0


def _compile_mlp(model, key):
    params = model.init(key)
    hidden_defs, _ = model.layers()
    graph = export_qmlp(hidden_defs, params["hidden"], params["head"],
                        meta={"model": type(model).__name__})
    return compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)


def _compile_conv(model, key, x_example):
    params = model.init(key)

    def fwd(p, x):
        out = model.apply(p, x, train=False)
        return out[0] if isinstance(out, tuple) else out

    cm = CompiledJaxModel(fwd, params, name=type(model).__name__)
    jax.block_until_ready(cm.offline(x_example))  # build the program
    return cm


def _time_offline(fn, xb, iters: int = 3) -> float:
    """Median queries/sec of fn over the batch."""
    jax.block_until_ready(fn(xb))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xb))
        times.append(time.perf_counter() - t0)
    times.sort()
    return xb.shape[0] / times[len(times) // 2]


def run():
    banner("Table 6: MLPerf-Tiny scenarios over compiled deployments")
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    entries = {}  # name -> (compiled, make_query, model_cost, bits, ref_fn)

    kws, ad = KWSMLP(), ADAutoencoder()
    for name, model, dim, bits in (("KWS-FINN", kws, 490, 3),
                                   ("AD-hls4ml", ad, 128, 8)):
        cm = _compile_mlp(model, key)
        mk = (lambda d: lambda i: rng.integers(-127, 128, (d,)).astype(np.int32))(dim)
        entries[name] = (cm, mk, model.cost(), bits, cm.reference)

    ic, cnv = ICModel(), CNVModel()
    x_img = jnp.ones((1, 32, 32, 3))
    for name, model, bits in (("IC-hls4ml", ic, 8), ("IC-FINN-CNV", cnv, 1)):
        cm = _compile_conv(model, key, x_img)
        mk = lambda i: rng.standard_normal((32, 32, 3)).astype(np.float32)
        entries[name] = (cm, mk, model.cost(), bits, cm.reference)

    rows = []
    for name, (cm, mk, cost, bits, ref_fn) in entries.items():
        conv = isinstance(cm, CompiledJaxModel)
        n_off = 64 if conv else 256

        ss = single_stream(cm.offline, mk, n_queries=16 if conv else 48,
                           model_cost=cost, bits=bits)
        off = offline(cm.offline, mk, n_samples=n_off,
                      model_cost=cost, bits=bits)

        # unfused per-layer baseline on the same Offline pool
        xb = np.stack([mk(i) for i in range(n_off)])
        if not conv:
            xb = jnp.asarray(xb, jnp.int32)
        ref_qps = _time_offline(ref_fn, np.asarray(xb) if conv else xb, iters=1)
        speedup = off.throughput_qps / max(ref_qps, 1e-9)

        rows.append(row(
            f"table6/{name}/SingleStream", ss.p50_ms * 1e3,
            p50_ms=f"{ss.p50_ms:.3f}", p99_ms=f"{ss.p99_ms:.3f}",
            roofline_uJ=f"{ss.energy_proxy_uJ:.2f}"))
        rows.append(row(
            f"table6/{name}/Offline", 0.0,
            compiled_qps=f"{off.throughput_qps:.0f}",
            unfused_ref_qps=f"{ref_qps:.0f}",
            compiled_speedup=f"{speedup:.1f}x",
            beats_reference=speedup > 1.0))
    print_rows(rows)

    # -- streaming mode: the FIFO pass feeding a real schedule -------------
    cm, mk, _, _, _ = entries["KWS-FINN"]
    xb = jnp.asarray(np.stack([mk(i) for i in range(64)]), jnp.int32)
    y_off = cm.offline(xb)
    y_str, stats = cm.streaming(xb, micro_batch=8)
    print(f"streaming[KWS]: fifo_depths={stats.fifo_depths} "
          f"max_occupancy={stats.max_occupancy} "
          f"sim_cycles={stats.sim_cycles} "
          f"matches_offline={bool(jnp.all(y_off == y_str))}")

    # -- multi-tenant: all four models behind one queue --------------------
    server = TinyModelServer({n: e[0] for n, e in entries.items()},
                             max_batch=16)
    for i in range(96):
        name = list(entries)[i % len(entries)]
        server.submit(name, entries[name][1](i))
    server.run_until_drained()
    st = server.stats()
    agg = st.pop("_aggregate")
    tenants = " ".join(f"{n}:p99={v['p99_ms']:.1f}ms" for n, v in st.items())
    print(f"multitenant: {agg['n']} reqs {agg['throughput_qps']:.0f} qps  {tenants}")
    return rows


if __name__ == "__main__":
    run()
