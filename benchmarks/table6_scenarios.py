"""Beyond-paper Table 6: MLPerf-Tiny load scenarios over compiled deployments.

The paper reports single-inference latency/energy (Table 5). MLPerf Tiny
actually scores submissions under LoadGen scenarios; this section runs the
full sweep — SingleStream / MultiStream / Offline / Server — for all four
Table-1 models through ``repro.deploy``, every one of them on the real
compiler path:

  * KWS + AD:   QAT export -> QIR json -> streamline/fuse -> jit schedule
    (``export_qmlp``), all-dense fused threshold stages.
  * IC + CNV:   ``export_qcnn`` -> im2col fused conv threshold stages (with
    calibrated po2 activation scales for IC and FINN-style bipolar sign
    banks for the binary CNV) + integer MaxPool / Flatten stages.

Every Offline row compares the compiled executor against the unfused
per-node QIR interpreter (the "no compiler" baseline it must beat), checks
compiled-vs-unfused argmax parity, and carries a per-stage latency
breakdown (``stage_ms``) so conv-vs-dense stage costs are visible. The
energy proxy for compiled models comes from ``core.bops.schedule_cost`` —
Eq. 1 BOPs per lowered stage, conv stages included.

Conv models (IC, CNV) additionally compare the two conv lowerings head to
head on the same Offline pool: the fused direct-conv path (default; no
materialized im2col) vs ``conv_lowering="im2col"`` (patch matrix +
threshold_matmul), with the lowering-aware traffic model
(``ModelCost.traffic_bytes``) printed next to the measured speedup and
bit-exactness asserted between the two.

The streaming section runs every model through BOTH streaming executors —
the compiled segment-wave path (``streaming_compiled``: one jit program per
segment wave, no host loop) and the host queue-loop reference
(``streaming_host``) — at the micro-batch the FIFO-model autotuner
(``deploy.autotune``) picked, asserts the three-way bit-equality
(offline == host == compiled), and reports the compiled-vs-host speedup
next to the tuned micro-batch / conv ``block_h`` and the modeled FIFO
cycles / traffic bytes that chose them.

Everything is also emitted machine-readable to ``BENCH_scenarios.json``
(``REPRO_BENCH_DIR``) so the perf trajectory is tracked across PRs.

Set REPRO_FAST=1 for a reduced-size pass (CI / smoke).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, emit_json, print_rows, row
from repro.core.bops import schedule_cost
from repro.core.qir import export_qcnn, export_qmlp
from repro.deploy import compile_graph
from repro.deploy.autotune import autotune_model, probe_streaming
from repro.deploy.scenarios import offline, server_streaming, single_stream
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP
from repro.serve import measure_wave_service_s
from repro.serving.engine import TinyModelServer

IN_SCALE = 1.0 / 127.0
FAST = os.environ.get("REPRO_FAST", "0") not in ("0", "")


def _compile_mlp(model, key):
    params = model.init(key)
    hidden_defs, _ = model.layers()
    graph = export_qmlp(hidden_defs, params["hidden"], params["head"],
                        meta={"model": type(model).__name__},
                        freeze_scales=True, in_scale=IN_SCALE)
    return compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)


def _compile_conv(model, key, rng, conv_lowering=None):
    params = model.init(key)
    cal = rng.integers(-127, 128, (8, model.in_hw, model.in_hw,
                                   model.in_ch)).astype(np.int32)
    graph = export_qcnn(model, params, calibrate=cal)
    return compile_graph(graph, in_scale=graph.meta["in_scale"],
                         use_pallas=False, conv_lowering=conv_lowering)


def _time_offline(fn, xb, iters: int = 5) -> float:
    """Median queries/sec of fn over the batch."""
    jax.block_until_ready(fn(xb))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xb))
        times.append(time.perf_counter() - t0)
    times.sort()
    return xb.shape[0] / times[len(times) // 2]




def run():
    banner("Table 6: MLPerf-Tiny scenarios over compiled deployments")
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    entries = {}  # name -> (compiled, make_query, bits)

    kws, ad = KWSMLP(), ADAutoencoder()
    for name, model, dim, bits in (("KWS-FINN", kws, 490, 3),
                                   ("AD-hls4ml", ad, 128, 8)):
        cm = _compile_mlp(model, key)
        mk = (lambda d: lambda i: rng.integers(-127, 128, (d,)).astype(np.int32))(dim)
        entries[name] = (cm, mk, bits)

    ic, cnv = ICModel(), CNVModel()
    for name, model, bits in (("IC-hls4ml", ic, 8), ("IC-FINN-CNV", cnv, 1)):
        cm = _compile_conv(model, key, rng)
        hw, ch = model.in_hw, model.in_ch
        mk = (lambda h, c: lambda i: rng.integers(
            -127, 128, (h, h, c)).astype(np.int32))(hw, ch)
        entries[name] = (cm, mk, bits)

    rows = []
    scenario_json = {"rows": [], "streaming": [], "tuned": {},
                     "fast": FAST}
    for name, (cm, mk, bits) in entries.items():
        conv = cm.schedule.n_fused_conv > 0
        cost = schedule_cost(cm.schedule.stages)
        n_off = (16 if conv else 64) if FAST else (48 if conv else 256)
        n_ss = (8 if conv else 16) if FAST else (16 if conv else 48)

        ss = single_stream(cm.offline, mk, n_queries=n_ss,
                           model_cost=cost, bits=bits)
        off = offline(cm.offline, mk, n_samples=n_off,
                      model_cost=cost, bits=bits, compiled=cm)

        # unfused per-node baseline + parity on the same Offline pool
        n_ref = min(n_off, 8 if conv else n_off)   # eager conv is slow
        xb = jnp.asarray(np.stack([mk(i) for i in range(n_ref)]), jnp.int32)
        ref_qps = _time_offline(cm.reference, xb, iters=1)
        y_c = np.asarray(cm.offline(xb))
        y_r = np.asarray(cm.reference(xb))
        parity = float((np.argmax(y_c, -1) == np.argmax(y_r, -1)).mean())
        speedup = off.throughput_qps / max(ref_qps, 1e-9)

        rows.append(row(
            f"table6/{name}/SingleStream", ss.p50_ms * 1e3,
            p50_ms=f"{ss.p50_ms:.3f}", p99_ms=f"{ss.p99_ms:.3f}",
            roofline_uJ=f"{ss.energy_proxy_uJ:.2f}"))
        rows.append(row(
            f"table6/{name}/Offline", 0.0,
            compiled_qps=f"{off.throughput_qps:.0f}",
            unfused_ref_qps=f"{ref_qps:.0f}",
            compiled_speedup=f"{speedup:.1f}x",
            fused_stages=cm.schedule.n_fused,
            fused_conv=cm.schedule.n_fused_conv,
            argmax_parity=parity,
            beats_reference=speedup > 1.0))
        scenario_json["rows"].append(
            {"model": name, "single_stream": ss.row(), "offline": off.row(),
             "unfused_ref_qps": ref_qps, "compiled_speedup": speedup,
             "argmax_parity": parity})
        if off.stage_ms:
            top = sorted(off.stage_ms, key=lambda s: -s["ms"])[:3]
            print(f"stage_ms[{name}]: " + " ".join(
                f"{s['stage']}={s['ms']:.3f}ms" for s in top))

        # fused direct-conv vs im2col lowering, same graph, same pool
        if conv:
            cm_i2c = compile_graph(cm.graph,
                                   in_scale=cm.graph.meta["in_scale"],
                                   use_pallas=False, conv_lowering="im2col")
            xb_cmp = jnp.asarray(np.stack([mk(i) for i in range(n_off)]),
                                 jnp.int32)
            # one pass each: parity check doubles as the jit warm-up
            assert bool(jnp.all(cm.offline(xb_cmp) == cm_i2c.offline(xb_cmp)))
            qps_direct = _time_offline(cm.offline, xb_cmp)
            qps_i2c = _time_offline(cm_i2c.offline, xb_cmp)
            t_direct = cost.traffic_bytes
            t_i2c = schedule_cost(cm_i2c.schedule.stages).traffic_bytes
            rows.append(row(
                f"table6/{name}/Offline/conv_lowering", 0.0,
                fused_qps=f"{qps_direct:.0f}",
                im2col_qps=f"{qps_i2c:.0f}",
                fused_speedup=f"{qps_direct / max(qps_i2c, 1e-9):.2f}x",
                fused_traffic_B=f"{t_direct:.0f}",
                im2col_traffic_B=f"{t_i2c:.0f}",
                im2col_bytes_saved=f"{1 - t_direct / t_i2c:.0%}",
                beats_im2col=qps_direct > qps_i2c))
            scenario_json["rows"][-1]["conv_lowering"] = {
                "fused_qps": qps_direct, "im2col_qps": qps_i2c,
                "fused_traffic_bytes": t_direct,
                "im2col_traffic_bytes": t_i2c,
                "beats_im2col": bool(qps_direct > qps_i2c)}

    # -- streaming: tuned micro-batch, compiled segment waves vs the host
    #    queue loop, three-way bit-equality asserted --------------------------
    stream_rows = []
    for name, (cm, mk, _) in entries.items():
        conv = cm.schedule.n_fused_conv > 0
        n = (8 if conv else 16) if FAST else (16 if conv else 32)
        cfg = autotune_model(cm, batch=n)
        cm.apply_tuned(cfg)
        scenario_json["tuned"][name] = cfg.to_dict()
        xb = jnp.asarray(np.stack([mk(i) for i in range(n)]), jnp.int32)
        y_off = cm.offline(xb)
        y_cmp, st_c = cm.streaming_compiled(xb)           # tuned micro-batch
        y_host, st_h = cm.streaming_host(xb, micro_batch=st_c.micro_batch)
        assert bool(jnp.all(jnp.asarray(y_cmp) == jnp.asarray(y_off))), name
        assert bool(jnp.all(jnp.asarray(y_host) == jnp.asarray(y_off))), name
        t_cmp = probe_streaming(cm, xb, st_c.micro_batch, iters=5)
        t_host = probe_streaming(cm, xb, st_c.micro_batch, iters=5,
                                 runner=cm.streaming_host)
        speed = t_host / max(t_cmp, 1e-9)
        stream_rows.append(row(
            f"table6/{name}/Streaming", t_cmp * 1e6 / n,
            compiled_ms=f"{t_cmp * 1e3:.2f}",
            host_ms=f"{t_host * 1e3:.2f}",
            compiled_vs_host=f"{speed:.2f}x",
            tuned_micro_batch=st_c.micro_batch,
            tuned_block_h=cfg.block_h or "-",
            modeled_cycles=cfg.modeled_cycles,
            modeled_traffic_B=f"{cfg.modeled_traffic_bytes:.0f}",
            fifo_depths=str(st_h.fifo_depths),
            segments=str(st_c.segments),
            bit_exact=True))
        print(f"streaming[{name}]: mb={st_c.micro_batch} "
              f"block_h={cfg.block_h} fifo_depths={st_h.fifo_depths} "
              f"max_occupancy={st_h.max_occupancy} "
              f"sim_cycles={st_h.sim_cycles} "
              f"compiled_vs_host={speed:.2f}x matches_offline=True")
        scenario_json["streaming"].append({
            "model": name, "micro_batch": st_c.micro_batch,
            "block_h": cfg.block_h,
            "block_mn": cfg.block_mn,
            "compiled_ms": t_cmp * 1e3, "host_ms": t_host * 1e3,
            "compiled_vs_host_speedup": speed,
            "modeled_cycles": cfg.modeled_cycles,
            "modeled_traffic_bytes": cfg.modeled_traffic_bytes,
            "fifo_depths": st_h.fifo_depths,
            "max_occupancy": st_h.max_occupancy,
            "segments": st_c.segments,
            "bit_exact_vs_offline": True})

        # ServerStreaming: Poisson traffic through the dynamic batcher at
        # ~0.7x the measured wave capacity, served from the same compiled
        # segment programs — bit-exactness asserted padding included
        # (serve_bench.py sweeps the full load curve; this is the smoke row)
        svc_s = measure_wave_service_s(cm, st_c.micro_batch, iters=3)
        sr = server_streaming(
            cm, mk, qps=0.7 * st_c.micro_batch / svc_s,
            n_queries=16 if FAST else 48,
            max_wait_ms=max(2.0, 1.5 * svc_s * 1e3))
        assert sr.extras["bit_exact_vs_offline"], name
        stream_rows.append(row(
            f"table6/{name}/ServerStreaming", sr.p99_ms * 1e3,
            p50_ms=f"{sr.p50_ms:.3f}", p99_ms=f"{sr.p99_ms:.3f}",
            qps=f"{sr.throughput_qps:.0f}",
            offered_qps=f"{sr.extras['offered_qps']:.0f}",
            micro_batch=sr.extras["micro_batch"],
            wave_occupancy=f"{sr.extras['wave_occupancy']:.2f}",
            bit_exact=sr.extras["bit_exact_vs_offline"]))
        scenario_json["streaming"][-1]["server_streaming"] = sr.row()
    rows += stream_rows
    print_rows(rows)

    # -- multi-tenant: all four models behind one queue --------------------
    server = TinyModelServer({n: e[0] for n, e in entries.items()},
                             max_batch=16)
    for i in range(32 if FAST else 96):
        name = list(entries)[i % len(entries)]
        server.submit(name, entries[name][1](i))
    server.run_until_drained()
    st = server.stats()
    agg = st.pop("_aggregate")
    tenants = " ".join(
        f"{n}:p99={v['p99_ms']:.1f}ms occ={v['wave_occupancy']:.2f}"
        for n, v in st.items())
    print(f"multitenant: {agg['n']} reqs {agg['throughput_qps']:.0f} qps "
          f"(compiled wave path)  {tenants}")
    scenario_json["multitenant"] = {"n": agg["n"],
                                    "throughput_qps": agg["throughput_qps"]}
    emit_json("BENCH_scenarios.json", scenario_json)
    return rows


if __name__ == "__main__":
    run()
