"""Paper Table 1: submitted models — flow, precision, parameter count, and a
quality metric measured on the synthetic stand-in datasets.

Parameter counts are checked against the paper's exact numbers where the
paper gives them (CNV 1 542 848; KWS 259 584 weights)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, print_rows, row
from repro.core.codesign import train_tiny
from repro.data.synthetic import SyntheticMelWindows, SyntheticMFCC
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP


def _auc(scores, labels):
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=float)
    ranks[order] = np.arange(len(scores))
    pos = labels == 1
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    return (ranks[pos].sum() - n_pos * (n_pos - 1) / 2) / max(n_pos * n_neg, 1)


def _ad_quality(steps=120):
    model = ADAutoencoder()
    data = SyntheticMelWindows(seed=0)
    params = model.init(jax.random.PRNGKey(0))

    def loss_fn(ps, x):
        recon, _ = model.apply(ps, x, train=False)
        return jnp.mean(jnp.square(recon - x))

    params, _ = train_tiny(loss_fn, params,
                           lambda s: jnp.asarray(data.batch(s, 64)[0]),
                           steps=steps, lr=2e-3)
    x, y = data.batch(10_000, 400, anomaly_frac=0.25)
    return _auc(np.asarray(model.anomaly_score(params, jnp.asarray(x))), y)


def _kws_quality(steps=150):
    model = KWSMLP()
    data = SyntheticMFCC(seed=0)
    params = model.init(jax.random.PRNGKey(0))
    w = jnp.asarray(1.0 / data.class_probs())      # paper's weighted CE
    w = w / jnp.sum(w) * 12

    def loss_fn(ps, batch):
        x, y = batch
        logits, _ = model.apply(ps, x, train=False)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, y[:, None], 1)[:, 0]
        return jnp.mean((lse - lab) * w[y])

    def batch_fn(s):
        x, y = data.batch(s, 64)
        return jnp.asarray(x), jnp.asarray(y)

    params, _ = train_tiny(loss_fn, params, batch_fn, steps=steps, lr=2e-3)
    x, y = data.batch(77_777, 500, balanced=True)
    logits, _ = model.apply(params, jnp.asarray(x), train=False)
    return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean())


def run():
    banner("Table 1: submitted models (params / precision / quality)")
    paper = {
        "IC-hls4ml": dict(prec="8-12", params=58_115, quality="83.5% acc"),
        "IC-FINN-CNV": dict(prec="1", params=1_542_848, quality="84.5% acc"),
        "AD-hls4ml": dict(prec="6-12", params=22_285, quality="0.83 AUC"),
        "KWS-FINN": dict(prec="3", params=259_584, quality="82.5% acc"),
    }
    ad_auc = _ad_quality()
    kws_acc = _kws_quality()
    ours = {
        "IC-hls4ml": dict(params=sum(
            l.n_params for l in ICModel().cost().layers), quality="n/a (synthetic)"),
        "IC-FINN-CNV": dict(params=CNVModel().n_weights(), quality="n/a (synthetic)"),
        "AD-hls4ml": dict(params=ADAutoencoder().n_params(),
                          quality=f"{ad_auc:.2f} AUC*"),
        "KWS-FINN": dict(params=KWSMLP().n_weights(),
                         quality=f"{kws_acc:.1%} acc*"),
    }
    rows = []
    for name in paper:
        rows.append(row(
            f"table1/{name}",
            paper_params=paper[name]["params"],
            our_params=ours[name]["params"],
            match=("EXACT" if paper[name]["params"] == ours[name]["params"]
                   else f"{ours[name]['params']/paper[name]['params']:.2f}x"),
            precision_bits=paper[name]["prec"],
            paper_quality=paper[name]["quality"],
            our_quality_synthetic=ours[name]["quality"],
        ))
    print_rows(rows)
    print("* quality on synthetic stand-in data (real datasets unavailable "
          "offline) — relative signal only")
    return rows


if __name__ == "__main__":
    run()
