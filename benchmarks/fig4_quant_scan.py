"""Paper Fig. 4: quantization exploration for KWS — REAL QAT training runs at
each bit width on the synthetic MFCC stand-in, plotting validation accuracy
against BOPs.

This is the paper's key codesign result to reproduce qualitatively: accuracy
holds from FP32 down to ~3 bits, then falls off a cliff below 3 bits — and
BOPs shrink superlinearly with bit width."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, print_rows, row
from repro.core.codesign import train_tiny
from repro.data.synthetic import SyntheticMFCC
from repro.models.tiny import KWSMLP


def _train_at_bits(bits: int, steps: int = 160, dim: int = 64, width: int = 48):
    """Small same-structure KWS MLP for speed; 32 = float baseline."""
    model = KWSMLP(in_dim=dim, width=width, weight_bits=bits, act_bits=bits)
    data = SyntheticMFCC(dim=dim, seed=0)
    params = model.init(jax.random.PRNGKey(bits))
    w = jnp.asarray(1.0 / data.class_probs())
    w = w / jnp.sum(w) * 12

    def loss_fn(ps, batch):
        x, y = batch
        logits, _ = model.apply(ps, x, train=False)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, y[:, None], 1)[:, 0]
        return jnp.mean((lse - lab) * w[y])

    def batch_fn(s):
        x, y = data.batch(s, 64)
        return jnp.asarray(x), jnp.asarray(y)

    params, _ = train_tiny(loss_fn, params, batch_fn, steps=steps, lr=2e-3)
    x, y = data.batch(55_555, 600, balanced=True)
    logits, _ = model.apply(params, jnp.asarray(x), train=False)
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean())
    return acc, model.cost().bops


def run():
    banner("Fig 4: KWS quantization exploration (REAL QAT at each width)")
    rows = []
    results = {}
    for bits in (32, 8, 6, 4, 3, 2, 1):
        acc, bops = _train_at_bits(bits)
        results[bits] = acc
        rows.append(row(
            f"fig4/W{bits}A{bits}",
            accuracy=f"{acc:.3f}",
            bops=f"{bops:.3e}",
            paper_point=("FP32 ref" if bits == 32 else
                         "chosen (3-bit)" if bits == 3 else ""),
        ))
    cliff = results[3] - results[2]
    hold = results[32] - results[3]
    rows.append(row(
        "fig4/summary",
        acc_drop_fp32_to_3bit=f"{hold:.3f}",
        acc_drop_3bit_to_2bit=f"{cliff:.3f}",
        cliff_below_3_bits=bool(cliff > hold),
        paper_finding="accuracy holds to 3 bits, drops sharply below",
    ))
    print_rows(rows)
    return rows


if __name__ == "__main__":
    run()
