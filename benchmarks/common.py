"""Shared benchmark utilities: wall-clock timing of jitted callables,
uniform row formatting (name, us_per_call, derived), and machine-readable
artifact emission (``BENCH_*.json``) so the perf trajectory is tracked
across PRs instead of living only in stdout."""

from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess
import time
from typing import Any, Callable, Dict, List

import jax


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float = 0.0, **derived) -> Dict[str, Any]:
    return {"name": name, "us_per_call": us, "derived": derived}


def print_rows(rows: List[Dict[str, Any]]):
    for r in rows:
        d = ";".join(f"{k}={v}" for k, v in r["derived"].items())
        print(f"{r['name']},{r['us_per_call']:.1f},{d}")


def banner(title: str):
    print(f"\n=== {title} " + "=" * max(0, 70 - len(title)))


def bench_dir() -> str:
    """Where BENCH_*.json artifacts land (CI uploads them from here)."""
    return os.environ.get("REPRO_BENCH_DIR", ".")


def git_sha() -> str:
    """Short sha of HEAD, or "" outside a git checkout (artifacts must
    still be writable from an exported tree)."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip()
    except Exception:
        return ""


def provenance() -> Dict[str, str]:
    """Who/what/when produced an artifact: git sha, platform string, JAX
    version, device kind, UTC timestamp. Attached to every BENCH_*.json
    so a number can always be traced back to the code and machine that
    made it."""
    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:
        device_kind = "unknown"
    return {
        "git_sha": git_sha(),
        "platform": platform.platform(),
        "jax_version": jax.__version__,
        "device_kind": device_kind,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


def emit_json(name: str, payload: Dict[str, Any]) -> str:
    """Write one machine-readable benchmark artifact.

    ``payload`` gets a schema version, the platform fingerprint, and the
    run's provenance stamp (``provenance()``) attached so artifacts from
    different machines/PRs are comparable AND traceable. Returns the
    path written."""
    os.makedirs(bench_dir(), exist_ok=True)
    path = os.path.join(bench_dir(), name)
    doc = {
        "schema": 1,
        "backend": jax.default_backend(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "provenance": provenance(),
        **payload,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    print(f"wrote {path}")
    return path
