"""Shared benchmark utilities: wall-clock timing of jitted callables and
uniform row formatting (name, us_per_call, derived)."""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List

import jax


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float = 0.0, **derived) -> Dict[str, Any]:
    return {"name": name, "us_per_call": us, "derived": derived}


def print_rows(rows: List[Dict[str, Any]]):
    for r in rows:
        d = ";".join(f"{k}={v}" for k, v in r["derived"].items())
        print(f"{r['name']},{r['us_per_call']:.1f},{d}")


def banner(title: str):
    print(f"\n=== {title} " + "=" * max(0, 70 - len(title)))
