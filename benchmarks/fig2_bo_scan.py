"""Paper Fig. 2: Bayesian-optimization NAS scans of 1-/2-/3-stack IC models
in the (FLOPs, accuracy) plane.

The accuracy axis uses a calibrated surrogate (CIFAR-10 is unavailable
offline): accuracy saturates with filters/stacks, degrades with stride, with
budget-dependent noise — the documented qualitative shape of the paper's
scans. The cost axis is the REAL FLOPs count from core.bops for the sampled
architecture, so the Pareto geometry is genuine."""

from __future__ import annotations

import math

import numpy as np

from benchmarks.common import banner, print_rows, row
from repro.core.bops import conv_cost, dense_cost, ModelCost
from repro.core.search import Choice, bo_search, pareto_front, predictor_sweep
from repro.costmodel import features_from_model_cost, load_default


def ic_cost(n_stacks, filters, ksize, stride) -> ModelCost:
    layers, cin, hw = [], 3, 32
    for s in range(n_stacks):
        for i in range(3):
            st = stride if i == 2 else 1
            hw = max(-(-hw // st), 1)
            layers.append(conv_cost(f"s{s}c{i}", cin, filters, ksize, hw, hw))
            cin = filters
    layers.append(dense_cost("head", hw * hw * cin, 10))
    return ModelCost(layers)


def ic_flops(n_stacks, filters, ksize, stride):
    return ic_cost(n_stacks, filters, ksize, stride).flops


def surrogate_accuracy(cfg, budget, rng):
    """Calibrated to Fig. 2: filters dominate; large stride cheap but lossy;
    more stacks help slightly; noise shrinks with training budget."""
    f, k, s, n = cfg["filters"], cfg["kernel"], cfg["stride"], cfg["stacks"]
    acc = 0.88
    acc -= 0.25 * math.exp(-f / 12.0)           # filter saturation
    acc -= 0.035 * (s - 1)                      # stride hurts
    acc -= 0.02 * (k == 1)                      # 1x1-only hurts
    acc += 0.01 * (n - 1)                       # extra stacks help a bit
    return acc + rng.normal(0, 0.02 / math.sqrt(budget))


def run():
    banner("Fig 2: BO NAS scans (surrogate accuracy x real FLOPs)")
    rows = []
    for stacks in (1, 2, 3):
        space = [
            Choice("filters", (2, 4, 8, 16, 32)),
            Choice("kernel", (1, 2, 3)),
            Choice("stride", (1, 2, 4)),
            Choice("stacks", (stacks,)),
        ]
        best_cfg, hist = bo_search(surrogate_accuracy, space, n_trials=40,
                                   n_startup=10, seed=stacks)
        pts = [(ic_flops(c["stacks"], c["filters"], c["kernel"], c["stride"]),
                s) for c, s in hist]
        front = pareto_front(pts)
        best_acc = max(s for _, s in hist)
        front_pts = sorted((pts[i] for i in front), key=lambda p: p[0])
        rows.append(row(
            f"fig2/bo_scan_{stacks}stack",
            n_trials=len(hist),
            best_acc=f"{best_acc:.3f}",
            best_cfg=f"f{best_cfg['filters']}k{best_cfg['kernel']}s{best_cfg['stride']}",
            pareto_points=len(front),
            pareto_min_mflops=f"{front_pts[0][0]/1e6:.2f}",
            pareto_max_mflops=f"{front_pts[-1][0]/1e6:.2f}",
        ))
    # paper's chosen v0.7 model: 2-stack-ish, 12.8 MFLOPs, 83.5%
    rows.append(row("fig2/paper_v07_operating_point",
                    mflops=12.8, accuracy=0.835,
                    note="BO narrows to few-filter-dominated front, matching"))

    # -- predictor-evaluated codesign sweep: the same architecture space
    # crossed with the serving micro-batch, scored by the learned wave-cost
    # predictor instead of wall-clock (ROADMAP direction 5). Accuracy uses
    # the noise-free surrogate so the Pareto geometry is deterministic.
    predictor = load_default()
    space = [
        Choice("filters", (2, 4, 8, 16, 32)),
        Choice("kernel", (1, 2, 3)),
        Choice("stride", (1, 2, 4)),
        Choice("stacks", (1, 2, 3)),
        Choice("micro_batch", (1, 4, 16, 64)),
    ]

    def feature_fn(cfg):
        mc = ic_cost(cfg["stacks"], cfg["filters"], cfg["kernel"],
                     cfg["stride"])
        return features_from_model_cost(
            mc, cfg["micro_batch"], n_conv_stages=3 * cfg["stacks"])

    sweep = predictor_sweep(
        predictor.predict_ms, feature_fn, space, method="bo", n_trials=96,
        seed=0,
        accuracy_fn=lambda cfg: surrogate_accuracy(
            cfg, 10**8, np.random.default_rng(0)))
    best = sweep["best"]
    rows.append(row(
        "fig2/predictor_codesign_sweep",
        n_evaluated=sweep["n_evaluated"],
        best_cfg=(f"f{best['config']['filters']}k{best['config']['kernel']}"
                  f"s{best['config']['stride']}x{best['config']['stacks']}"
                  f"mb{best['config']['micro_batch']}"),
        best_predicted_ms=f"{best['predicted_ms']:.3f}",
        pareto_points=len(sweep["pareto"]),
        note="learned-cost sweep, zero wall-clock evaluations"))
    print_rows(rows)
    return rows


if __name__ == "__main__":
    run()
