"""Paper Table 4: the AD model optimization ladder — reference (wide, deep,
640-d input), +BN folding, +input downsampling, +depth/width reduction — with
AUC on the synthetic stand-in and compile-time resource analogues."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, print_rows, row
from repro.core.codesign import train_tiny
from repro.data.synthetic import SyntheticMelWindows
from repro.models.tiny import ADAutoencoder


def _auc(scores, labels):
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=float)
    ranks[order] = np.arange(len(scores))
    pos = labels == 1
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    return (ranks[pos].sum() - n_pos * (n_pos - 1) / 2) / max(n_pos * n_neg, 1)


def _train_and_eval(model: ADAutoencoder, dim: int, steps=120):
    data = SyntheticMelWindows(dim=dim, rank=8, seed=0)
    params = model.init(jax.random.PRNGKey(0))

    def loss_fn(ps, x):
        recon, _ = model.apply(ps, x, train=False)
        return jnp.mean(jnp.square(recon - x))

    params, losses = train_tiny(
        loss_fn, params, lambda s: jnp.asarray(data.batch(s, 64)[0]),
        steps=steps, lr=2e-3)
    x, y = data.batch(10_000, 400, anomaly_frac=0.25)
    auc = _auc(np.asarray(model.anomaly_score(params, jnp.asarray(x))), y)
    return auc, losses[-1]


def run():
    banner("Table 4: AD optimization ladder (synthetic AUC + params)")
    variants = {
        # paper reference: 640-d input, deeper/wider, float (32-bit here)
        "reference_float": (ADAutoencoder(in_dim=640, width=128, bottleneck=8,
                                          weight_bits=32, act_bits=32,
                                          use_bn=True), 640,
                            "87.1% AUC (paper)"),
        # with folding: QDenseBatchNorm fold + 8-bit QAT, still 640-d
        "with_folding": (ADAutoencoder(in_dim=640, width=128, bottleneck=8,
                                       weight_bits=8, act_bits=8), 640,
                         "68.1% AUC / 221063 LUT (paper)"),
        # with downsampling: 128-d input
        "with_downsampling": (ADAutoencoder(in_dim=128, width=128,
                                            weight_bits=8, act_bits=8), 128,
                              "81.4% AUC / 35366 LUT (paper)"),
        # all opt: 128-d, width 72, 5 hidden layers (the submitted model)
        "with_all_opt": (ADAutoencoder(in_dim=128, width=72,
                                       weight_bits=8, act_bits=8), 128,
                         "83.3% AUC / 31094 LUT (paper)"),
    }
    rows = []
    for name, (model, dim, paper) in variants.items():
        auc, final_loss = _train_and_eval(model, dim)
        rows.append(row(
            f"table4/{name}",
            auc_synthetic=f"{auc:.3f}",
            params=model.n_params(),
            weight_bits=model.weight_bits,
            final_train_loss=f"{final_loss:.4f}",
            paper_row=paper,
        ))
    print_rows(rows)
    return rows


if __name__ == "__main__":
    run()
