"""Paper Table 5: latency and energy per inference for each submitted model.

FPGA wall-clock/Joulescope measurements become the TPU-v5e roofline model
(latency = max(compute, memory) term; energy = board power x latency) from
core.codesign.deploy_report, next to the paper's measured Pynq-Z2 numbers.
A real CPU wall-time of the jitted batch-1 forward is reported as a sanity
column (relative ordering only)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import banner, print_rows, row, time_call
from repro.core.codesign import deploy_report
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP


def run():
    banner("Table 5: latency + energy per inference (TPU roofline model)")
    models = {
        "IC-hls4ml": (ICModel(), lambda m: (m, jnp.ones((1, 32, 32, 3))), 8,
                      "27.3 ms / 44330 uJ (paper Pynq-Z2)"),
        "IC-FINN-CNV": (CNVModel(), lambda m: (m, jnp.ones((1, 32, 32, 3))), 1,
                        "1.5 ms / 2535 uJ (paper)"),
        "AD-hls4ml": (ADAutoencoder(), lambda m: (m, jnp.ones((1, 128))), 8,
                      "19 us / 30.1 uJ (paper)"),
        "KWS-FINN": (KWSMLP(), lambda m: (m, jnp.ones((1, 490))), 3,
                     "17 us / 30.9 uJ (paper)"),
    }
    rows = []
    for name, (model, mk, bits, paper) in models.items():
        m, x = mk(model)
        params = m.init(jax.random.PRNGKey(0))

        def fwd(p, x):
            out = m.apply(p, x, train=False)
            return out[0] if isinstance(out, tuple) else out

        us_cpu = time_call(jax.jit(fwd), params, x)
        rep = deploy_report(m.cost(), batch=1, bits=bits)
        rows.append(row(
            f"table5/{name}", us_cpu,
            tpu_roofline_latency_us=f"{rep['latency_us']:.2f}",
            tpu_energy_uJ=f"{rep['energy_uJ']:.2f}",
            bound=rep["bound"],
            bops=f"{rep['bops']:.3e}",
            wm_kbits=f"{rep['wm_bits']/1e3:.0f}",
            paper_row=paper,
        ))
    print_rows(rows)
    print("note: tiny batch-1 models are memory-bound on TPU (weights stream "
          "dominates), same conclusion as the paper's on-chip-weights design")
    return rows


if __name__ == "__main__":
    run()
