"""Benchmark harness entry point: one section per paper table/figure, plus
the kernel traffic bench and the dry-run roofline table.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table1,fig4

Each section prints CSV rows ``name,us_per_call,derived`` (common.print_rows)
so downstream tooling can grep a stable format.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    costmodel_bench,
    fig2_bo_scan,
    fig3_asha_scan,
    fig4_quant_scan,
    kernel_bench,
    obs_bench,
    serve_bench,
    table1_models,
    table2_fifo,
    table3_fusion,
    table4_ad_opts,
    table5_latency_energy,
    table6_scenarios,
)

SECTIONS = {
    "table1": table1_models.run,
    "table2": table2_fifo.run,
    "table3": table3_fusion.run,
    "table4": table4_ad_opts.run,
    "table5": table5_latency_energy.run,
    "table6": table6_scenarios.run,
    "fig2": fig2_bo_scan.run,
    "fig3": fig3_asha_scan.run,
    "fig4": fig4_quant_scan.run,
    "kernels": kernel_bench.run,
    "serve": serve_bench.run,
    "obs": obs_bench.run,
    "costmodel": costmodel_bench.run,
}


def _roofline_section():
    """Render the dry-run roofline tables (paper-faithful baseline AND the
    beyond-paper optimized re-sweep) if artifacts exist."""
    import os

    from repro.launch.roofline import analyze, load_artifacts, render_table

    if not os.path.isdir("artifacts/dryrun"):
        print("roofline: no artifacts/dryrun — run repro.launch.dryrun first")
        return []
    rows = [analyze(r) for r in load_artifacts("artifacts/dryrun")]
    print("--- paper-faithful baseline ---")
    print(render_table(rows, mesh="single", tag="baseline"))
    if any(r.tag == "optimized" for r in rows):
        print("\n--- beyond-paper optimized (MoE combine-then-psum + causal "
              "block-packing) ---")
        print(render_table(rows, mesh="single", tag="optimized"))
    if any(r.tag == "serving" for r in rows):
        print("\n--- decode cells under the serving layout "
              "(tponly + int8 weights) ---")
        print(render_table(rows, mesh="single", tag="serving"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    args = ap.parse_args()
    names = list(SECTIONS) + ["roofline"]
    if args.only:
        names = [n.strip() for n in args.only.split(",")]

    t0 = time.time()
    failures = []
    for name in names:
        try:
            if name == "roofline":
                from benchmarks.common import banner

                banner("Roofline table (from dry-run artifacts)")
                _roofline_section()
            else:
                SECTIONS[name]()
        except Exception:  # noqa: BLE001 — report all sections
            failures.append(name)
            traceback.print_exc()
    print(f"\n[benchmarks] done in {time.time()-t0:.1f}s; "
          f"{len(names)-len(failures)}/{len(names)} sections ok")
    if failures:
        print(f"[benchmarks] FAILED sections: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
