"""Paper Table 2: FIFO buffer sizes chosen by the depth-optimization pass for
each submitted model's dataflow pipeline (simulate big -> record max ->
shrink to max+1)."""

from __future__ import annotations

from benchmarks.common import banner, print_rows, row
from repro.core.dataflow import (
    conv_pipeline_stages,
    mlp_pipeline_stages,
    optimize_fifo_depths,
)


def run():
    banner("Table 2: FIFO buffer depth optimization")
    pipelines = {
        # AD autoencoder (paper: FIFO opt disabled, size 1 — we run it anyway
        # to show what the pass would pick)
        "AD-hls4ml": mlp_pipeline_stages([128, 72, 72, 8, 72, 72, 128],
                                         reuse_factor=144),
        # KWS MLP (paper range 32-64)
        "KWS-FINN": mlp_pipeline_stages([490, 256, 256, 256, 12],
                                        reuse_factor=8),
        # IC conv stacks: (in_elems, out_elems, ii, latency) per stage
        "IC-hls4ml": conv_pipeline_stages([
            (32 * 32 * 3, 32 * 32 * 32, 4, 8),
            (32 * 32 * 32, 32 * 32 * 4, 4, 8),
            (32 * 32 * 4, 32 * 32 * 32, 8, 16),
            (32 * 32 * 32, 8 * 8 * 32, 16, 32),
            (8 * 8 * 32, 8 * 8 * 4, 4, 8),
        ]),
        "IC-FINN-CNV": conv_pipeline_stages([
            (32 * 32 * 3, 30 * 30 * 64, 2, 4),
            (30 * 30 * 64, 28 * 28 * 64, 2, 4),
            (14 * 14 * 64, 12 * 12 * 128, 2, 4),
            (12 * 12 * 128, 10 * 10 * 128, 2, 4),
            (5 * 5 * 128, 3 * 3 * 256, 2, 4),
            (3 * 3 * 256, 1 * 1 * 256, 2, 4),
        ]),
    }
    paper_sizes = {"AD-hls4ml": "1 (opt disabled)", "KWS-FINN": "32-64",
                   "IC-hls4ml": "1-1066", "IC-FINN-CNV": "2-512"}
    rows = []
    for name, stages in pipelines.items():
        n_tok = max(s.elems_in for s in stages) * 2
        res = optimize_fifo_depths(stages, n_tokens=n_tok)
        d = res["optimized_depths"]
        rows.append(row(
            f"table2/{name}",
            fifo_min=min(d), fifo_max=max(d),
            total_buffer_elems=res["total_buffer_elems"],
            throughput_preserved=res["throughput_preserved"],
            cycles=res["optimized_cycles"],
            paper_fifo_sizes=paper_sizes[name],
        ))
    print_rows(rows)
    return rows


if __name__ == "__main__":
    run()
