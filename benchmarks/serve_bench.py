"""Serving benchmark: throughput-at-SLO curves over the dynamic batcher.

The first benchmark gated on *tail latency under load* rather than
single-query speed: every Table-1 model is compiled, autotuned, given an
SLO-constrained operating point (``deploy.autotune.slo_micro_batch`` — the
largest wave whose modeled fill+drain fits the p99 budget), and then
driven through the ``repro.serve`` router with Poisson arrivals at a sweep
of load fractions of its modeled saturation throughput. Each point reports
p50/p90/p99 latency, achieved throughput, shed rate, and wave occupancy —
and asserts the wave-padding contract by checking every served result
bit-exact against ``offline`` (``server_streaming`` does the comparison,
padded partial waves included).

The **operating point** per model is the largest swept load whose p99
stayed inside the budget with shed rate < 1% — the "throughput at SLO"
number a capacity planner would quote. Everything lands machine-readable
in ``BENCH_serving.json`` (``REPRO_BENCH_DIR``) next to the scenario and
kernel artifacts so the serving trajectory is tracked across PRs.

The **replica-scaling sweep** answers the scale-out question the single
replica curves cannot: how does throughput-at-SLO grow with replica
count? The container exposes one physical device, so the sweep is a
discrete-event simulation — ``repro.serve.sim.ScriptedWaveModel`` fakes
under a ``ManualClock``, with each fake's wave service time anchored to
the family's *measured* wave service on the real compiled model. The
async engine overlaps waves across the pool (throughput scales with N);
the sync engine rows show the blocking router's one-wave-at-a-time
ceiling that PR 8 removed. Standalone: ``python -m benchmarks.serve_bench
--scaling`` (emits ``BENCH_serving_scaling.json``); the full run embeds
the same sweep under the ``"scaling"`` key of ``BENCH_serving.json``.

Set REPRO_FAST=1 for a reduced-size pass (CI / smoke).
"""

from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from benchmarks.common import banner, emit_json, print_rows, row
from benchmarks.table6_scenarios import _compile_conv, _compile_mlp
from repro.deploy.autotune import autotune_model
from repro.deploy.scenarios import server_streaming
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP
from repro.serve import (
    AsyncEngine,
    ManualClock,
    PredictedServiceModel,
    Router,
    RouterConfig,
    ServiceModel,
    SyncEngine,
    measure_wave_service_s,
    poisson_trace,
    slo_operating_point,
)
from repro.serve.sim import scripted_pool

FAST = os.environ.get("REPRO_FAST", "0") not in ("0", "")

#: Swept offered-load fractions of the modeled saturation throughput.
LOAD_FRACTIONS = (0.7, 1.1) if FAST else (0.3, 0.5, 0.7, 0.9, 1.1)

#: Shed-rate ceiling for a load point to count as "inside SLO".
SHED_CEILING = 0.01

#: Replica counts for the scaling sweep.
SCALING_REPLICAS = (1, 2, 4)

#: Scaling-sweep load fractions of the *aggregate* (replicas x per-replica
#: saturation) throughput — lower than LOAD_FRACTIONS because the sync
#: contrast rows need sub-ceiling points to land a valid operating point.
#: Not reduced under FAST: the sweep is a pure event loop, and a coarse
#: fraction grid makes the operating point (and the 1->2 scaling ratio)
#: a lottery on whichever single point survives the SLO filter.
SCALING_FRACTIONS = (0.25, 0.4, 0.6, 0.7, 0.8, 0.95)

#: Queries per scaling simulation point (pure event loop — cheap).
#: ``bench_scaling`` raises this to 40 waves' worth when the tuned wave
#: is large, so the p99 of a point never rests on a handful of waves.
SCALING_QUERIES = 160 if FAST else 400


def _budget_ms(service: ServiceModel, micro_batch: int) -> float:
    """Per-model p99 budget: 6x the modeled tuned-wave service time,
    floored at 10 ms. Derived (not hard-coded) so the same bench stays
    meaningful across machines an order of magnitude apart."""
    return max(10.0, 6.0 * service.wave_service_s(micro_batch) * 1e3)


def bench_model(name: str, cm, mk, n_queries: int):
    cfg = autotune_model(cm, batch=32 if FAST else 64)
    cm.apply_tuned(cfg)
    # model-first service estimate, pinned to reality by ONE measured wave
    # probe at the tuned wave size — stage compute alone misses the
    # per-wave dispatch overhead that dominates small models on CPU, and a
    # capacity plan from the raw model would sweep pure overload
    service = ServiceModel.from_compiled(cm, probe_batch=8)
    tuned_mb = cm.default_micro_batch
    service = service.recalibrated(
        measure_wave_service_s(cm, tuned_mb), tuned_mb)
    budget = _budget_ms(service, tuned_mb)
    # the wave's own service may take at most ~25% of the budget: the
    # admission estimate adds the batching wait (1.5x service below) and
    # queued waves on top, and est(empty queue) must clear the budget or
    # the controller sheds everything before the first wave forms.
    # Fixed-point-iterate the choice: dispatch overhead is flat across
    # wave sizes, so a model calibrated at the tuned wave is optimistic
    # about smaller waves — re-measure at the chosen wave until it
    # settles, and the modeled saturation the sweep scales is honest.
    point = slo_operating_point(service, 0.25 * budget)
    mb = int(point["micro_batch"])
    for _ in range(2):
        service = service.recalibrated(measure_wave_service_s(cm, mb), mb)
        point = slo_operating_point(service, 0.25 * budget)
        if int(point["micro_batch"]) == mb:
            break
        mb = int(point["micro_batch"])
    # deadline long enough that full waves can form at sub-saturation load
    max_wait_ms = max(2.0, 1.5 * service.wave_service_s(mb) * 1e3)

    # honest saturation: drive the router itself far past the modeled
    # peak with shedding off — back-to-back full waves through the real
    # dispatch loop (router bookkeeping included) — and read the achieved
    # throughput back as the capacity the sweep scales. The service model
    # is pinned to that number too, so the admission controller and the
    # offered load agree about what a wave really costs end to end.
    probe = server_streaming(
        cm, mk, qps=3.0 * service.saturation_qps(mb),
        n_queries=n_queries, seed=17, max_wait_ms=max_wait_ms,
        micro_batch=mb, warmup=1)
    sat_qps = probe.throughput_qps
    service = service.recalibrated(mb / sat_qps, mb)
    budget = max(budget, 3.5 * service.wave_service_s(mb) * 1e3)
    max_wait_ms = max(2.0, 1.5 * service.wave_service_s(mb) * 1e3)

    curve = []
    for frac in LOAD_FRACTIONS:
        rep = server_streaming(
            cm, mk, qps=frac * sat_qps, n_queries=n_queries,
            seed=int(frac * 100), max_wait_ms=max_wait_ms,
            p99_budget_ms=budget, micro_batch=mb, service_model=service)
        curve.append({
            "load_fraction": frac,
            "offered_qps": rep.extras["offered_qps"],
            "achieved_qps": rep.throughput_qps,
            "p50_ms": rep.p50_ms, "p90_ms": rep.p90_ms, "p99_ms": rep.p99_ms,
            "shed_rate": rep.extras["shed_rate"],
            "served": rep.extras["served"], "shed": rep.extras["shed"],
            "wave_occupancy": rep.extras["wave_occupancy"],
            "met_slo": rep.extras["met_slo"],
            "bit_exact_vs_offline": rep.extras.get("bit_exact_vs_offline"),
        })

    inside = [c for c in curve
              if c["met_slo"] and c["shed_rate"] < SHED_CEILING]
    op = max(inside, key=lambda c: c["achieved_qps"]) if inside else None
    return {
        "micro_batch": mb,
        "p99_budget_ms": budget,
        "max_wait_ms": max_wait_ms,
        "measured_saturation_qps": sat_qps,
        "wave_service_ms": service.wave_service_s(mb) * 1e3,
        "service_calibration": service.calibration,
        "slo_candidates": point["candidates"],
        "curve": curve,
        "operating_point": op,
    }


# ---------------------------------------------------------------------------
# replica-scaling sweep (discrete-event simulation, measured service anchor)
# ---------------------------------------------------------------------------

def _scaling_service_model(service_s: float, mb: int) -> ServiceModel:
    """One-stage ServiceModel calibrated so ``wave_service_s(mb)`` equals
    ``service_s`` exactly — the scripted lane gets the same admission and
    placement arithmetic a probe-calibrated real model would."""
    model = ServiceModel(works=[("s", 0)], sec_per_cycle=1.0)
    model.sec_per_cycle = service_s / max(model.wave_cycles(mb), 1)
    return model


def _scaling_point(service_s: float, mb: int, n_replicas: int, engine_cls,
                   frac: float, budget_ms: float, max_wait_ms: float,
                   n_queries: int, seed: int):
    """One simulated load point: ``n_replicas`` scripted replicas (each a
    hand-checkable ``service_s``-per-wave device), Poisson arrivals at
    ``frac`` of the pool's aggregate saturation, full router in the loop
    (admission, deadline batching, placement, reaping)."""
    clock = ManualClock()
    pool = scripted_pool(clock, [service_s] * n_replicas, micro_batch=mb)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=max_wait_ms, micro_batch=mb,
                     p99_budget_ms=budget_ms),
        clock=clock,
        service_models={"m": _scaling_service_model(service_s, mb)},
        engine=engine_cls())
    offered = frac * n_replicas * (mb / service_s)
    trace = poisson_trace(qps=offered, n=n_queries, seed=seed)
    reqs = router.run_trace(
        "m", trace, lambda i: np.full((2,), i % 128, np.int32))
    served = [r for r in reqs if not r.shed]
    lats_ms = np.asarray([r.latency_s for r in served]) * 1e3
    span = (max(r.done_t for r in served)
            - min(r.arrival_t for r in served)) if served else 0.0
    p99 = float(np.percentile(lats_ms, 99)) if served else float("inf")
    return {
        "load_fraction": frac,
        "offered_qps": offered,
        "achieved_qps": len(served) / max(span, 1e-12),
        "p99_ms": p99,
        "shed_rate": 1.0 - len(served) / len(reqs),
        "met_slo": bool(served) and p99 <= budget_ms,
    }


def bench_scaling(name: str, service_s: float, mb: int,
                  n_queries: int = SCALING_QUERIES):
    """Throughput-at-p99-SLO vs replica count for one model family, async
    vs sync engine. ``service_s`` is the family's measured wave service
    time on the real compiled model — the simulation's only free
    parameter, so the sweep isolates engine scheduling from device count.
    """
    budget_ms = max(10.0, 6.0 * service_s * 1e3)
    max_wait_ms = max(2.0, 1.5 * service_s * 1e3)
    n_queries = max(n_queries, 40 * mb)
    out = {"wave_service_ms": service_s * 1e3, "micro_batch": mb,
           "p99_budget_ms": budget_ms, "max_wait_ms": max_wait_ms,
           "replica_counts": list(SCALING_REPLICAS),
           "load_fractions": list(SCALING_FRACTIONS),
           "n_queries": n_queries, "engines": {}}
    for engine_name, engine_cls in (("async", AsyncEngine),
                                    ("sync", SyncEngine)):
        per_n = {}
        for n in SCALING_REPLICAS:
            curve = [
                _scaling_point(
                    service_s, mb, n, engine_cls, frac, budget_ms,
                    max_wait_ms, n_queries,
                    seed=10_000 * n + int(frac * 1000)
                    + (5_000 if engine_cls is SyncEngine else 0))
                for frac in SCALING_FRACTIONS]
            inside = [c for c in curve
                      if c["met_slo"] and c["shed_rate"] < SHED_CEILING]
            per_n[str(n)] = {
                "curve": curve,
                "qps_at_slo": (max(c["achieved_qps"] for c in inside)
                               if inside else None),
            }
        out["engines"][engine_name] = per_n
    a = out["engines"]["async"]
    base = a["1"]["qps_at_slo"]
    if base:
        for n in SCALING_REPLICAS[1:]:
            qn = a[str(n)]["qps_at_slo"] or 0.0
            out[f"scaling_1_to_{n}"] = qn / base
    return out


# ---------------------------------------------------------------------------
# degraded capacity: 1-of-2 replicas killed mid-trace (fault handling bench)
# ---------------------------------------------------------------------------

def bench_faults(service_s: float, mb: int, n_queries: int = 200,
                 seed: int = 23):
    """Kill one of two replicas halfway through a Poisson trace at 0.7x
    the *aggregate* saturation and report the degradation: pre- vs
    post-kill p99, shed rate, and the zero-lost guarantee (every admitted
    request is either served or shed with a typed reason — never hung,
    never silently dropped). Discrete-event simulation anchored to the
    measured wave service like the scaling sweep, so the row is exact and
    reproducible."""
    from repro.serve import FaultPlan, FaultSpec
    from repro.serve.replica import QUARANTINED

    budget_ms = max(10.0, 6.0 * service_s * 1e3)
    max_wait_ms = max(2.0, 1.5 * service_s * 1e3)
    offered = 0.7 * 2 * (mb / service_s)
    trace = poisson_trace(qps=offered, n=n_queries, seed=seed)
    t_kill = float(np.asarray(trace.arrivals)[n_queries // 2])
    clock = ManualClock()
    plan = FaultPlan([FaultSpec("replica_crash", replica=0,
                                after_t=t_kill,
                                duration_s=float("inf"))])
    pool = scripted_pool(clock, [service_s] * 2, micro_batch=mb,
                         plan=plan)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=max_wait_ms, micro_batch=mb,
                     p99_budget_ms=budget_ms, wave_timeout_mult=3.0,
                     retry_backoff_ms=0.5, max_retries=2),
        clock=clock,
        service_models={"m": _scaling_service_model(service_s, mb)},
        engine=AsyncEngine())
    reqs = router.run_trace(
        "m", trace, lambda i: np.full((2,), i % 128, np.int32))

    lost = [r for r in reqs if not r.shed and r.result is None]
    if lost:
        # the headline guarantee of the fault-handling PR; a bench that
        # quietly published rows past this would be lying about it
        raise RuntimeError(
            f"fault bench lost {len(lost)} admitted requests "
            f"(uids {[r.uid for r in lost[:8]]}) — the zero-lost "
            "guarantee is broken")

    def _stats(rs):
        served = [r for r in rs if not r.shed]
        lats = np.asarray([r.latency_s for r in served]) * 1e3
        return {
            "n": len(rs), "served": len(served),
            "shed_rate": 1.0 - len(served) / len(rs) if rs else 0.0,
            "p99_ms": float(np.percentile(lats, 99)) if served else None,
        }

    snap = router.stats()["m"]["metrics"]
    return {
        "offered_qps": offered, "micro_batch": mb,
        "wave_service_ms": service_s * 1e3,
        "p99_budget_ms": budget_ms, "t_kill_s": t_kill,
        "pre_kill": _stats([r for r in reqs if r.arrival_t < t_kill]),
        "post_kill": _stats([r for r in reqs if r.arrival_t >= t_kill]),
        "fault_counts": dict(snap.fault_counts),
        "shed_reasons": dict(snap.shed_reasons),
        "killed_replica_quarantined":
            pool.replicas[0].health == QUARANTINED,
        "zero_lost": True,
    }


# ---------------------------------------------------------------------------
# cold start: predictor-priced admission from wave 0 vs the unpriced path
# ---------------------------------------------------------------------------

def _fleet_predicted_service_s(entries, measured, cold_name: str) -> float:
    """Predict the cold family's wave service from the REST of the fleet.

    The fleet story end to end: train a ``repro.costmodel`` predictor on
    the other families' measured wave anchors (features from their static
    compiled structure), then price the cold family's wave having never
    measured it — leave-one-out at the fleet level, exactly what a server
    must do for a model it has never seen."""
    from repro.costmodel import WaveCostPredictor, wave_features

    rows = []
    for name, (cm, mk) in entries.items():
        if name == cold_name:
            continue
        m = measured[name]
        rows.append({"model": name,
                     "features": wave_features(cm, m["micro_batch"]),
                     "measured_ms": m["wave_service_ms"]})
    pred = WaveCostPredictor.fit_rows(rows, l2=1.0, seed=0, n_members=4)
    cold_cm = entries[cold_name][0]
    mb = measured[cold_name]["micro_batch"]
    return float(pred.predict_ms(wave_features(cold_cm, mb))) / 1e3


def bench_cold_start(service_s: float, predicted_s: float, mb: int,
                     n_queries: int = 480, seed: int = 31):
    """A cold model at overload, with vs without predictor-priced admission.

    Both runs are exact discrete-event simulations (``ManualClock`` +
    scripted replica) of the same overloaded Poisson trace (2.5x
    saturation) against a model the server has NEVER measured. The p99
    budget is priced off the *prediction* (3x predicted service) — the
    only service number a cold model has; pricing it off the true
    service would assume the very measurement cold start lacks, and an
    overestimating predictor would then shed everything and starve the
    EWMA of the samples it needs to correct. The "predicted" run prices
    admission from wave 0 with a ``PredictedServiceModel`` anchored on
    the fleet predictor's estimate (the SLO controller's EWMA then
    corrects toward the true service online); the "unpriced" run is the
    status quo for an unmeasured model — no admission control, so
    overload queues instead of shedding and the p99 blows through the
    budget. The headline numbers are the p99 and shed-rate deltas
    between the two."""
    budget_ms = max(5.0, 3.0 * predicted_s * 1e3)
    max_wait_ms = max(2.0, 1.5 * predicted_s * 1e3)
    offered = 2.5 * (mb / service_s)
    trace = poisson_trace(qps=offered, n=n_queries, seed=seed)
    out = {"offered_qps": offered, "load_fraction": 2.5,
           "micro_batch": mb, "wave_service_ms": service_s * 1e3,
           "predicted_wave_ms": predicted_s * 1e3,
           "prediction_rel_err": abs(predicted_s - service_s) / service_s,
           "p99_budget_ms": budget_ms, "n_queries": n_queries}
    for label, priced in (("predicted", True), ("unpriced", False)):
        clock = ManualClock()
        pool = scripted_pool(clock, [service_s], micro_batch=mb)
        router = Router(
            {"m": pool},
            RouterConfig(max_wait_ms=max_wait_ms, micro_batch=mb,
                         p99_budget_ms=budget_ms if priced else None),
            clock=clock,
            service_models={"m": PredictedServiceModel.from_table(
                [("s", 0)], {mb: predicted_s})} if priced else None,
            engine=AsyncEngine())
        reqs = router.run_trace(
            "m", trace, lambda i: np.full((2,), i % 128, np.int32))
        served = [r for r in reqs if not r.shed]
        lats_ms = np.asarray([r.latency_s for r in served]) * 1e3
        p99 = float(np.percentile(lats_ms, 99)) if served else None
        out[label] = {
            "served": len(served),
            "shed_rate": 1.0 - len(served) / len(reqs),
            "p99_ms": p99,
            "met_slo": p99 is not None and p99 <= budget_ms,
        }
    if (out["predicted"]["p99_ms"] is not None
            and out["unpriced"]["p99_ms"] is not None):
        out["p99_delta_ms"] = (out["unpriced"]["p99_ms"]
                               - out["predicted"]["p99_ms"])
    out["shed_rate_delta"] = (out["predicted"]["shed_rate"]
                              - out["unpriced"]["shed_rate"])
    return out


def _build_entries(key, rng):
    entries = {}
    kws, ad = KWSMLP(), ADAutoencoder()
    for name, model, dim in (("KWS-FINN", kws, 490), ("AD-hls4ml", ad, 128)):
        cm = _compile_mlp(model, key)
        mk = (lambda d: lambda i: rng.integers(
            -127, 128, (d,)).astype(np.int32))(dim)
        entries[name] = (cm, mk)
    for name, model in (("IC-hls4ml", ICModel()), ("IC-FINN-CNV", CNVModel())):
        cm = _compile_conv(model, key, rng)
        hw, ch = model.in_hw, model.in_ch
        mk = (lambda h, c: lambda i: rng.integers(
            -127, 128, (h, h, c)).astype(np.int32))(hw, ch)
        entries[name] = (cm, mk)
    return entries


def _scaling_rows(name: str, sc) -> list:
    """Printable summary rows for one family's scaling sweep."""
    a = sc["engines"]["async"]
    s = sc["engines"]["sync"]

    def q(tab, n):
        v = tab[str(n)]["qps_at_slo"]
        return "-" if v is None else f"{v:.0f}"

    return [row(
        f"serve/{name}/scaling", 0.0,
        wave_ms=f"{sc['wave_service_ms']:.3f}",
        micro_batch=sc["micro_batch"],
        async_qps_1=q(a, 1), async_qps_2=q(a, 2), async_qps_4=q(a, 4),
        sync_qps_2=q(s, 2),
        x_1_to_2=(f"{sc['scaling_1_to_2']:.2f}"
                  if "scaling_1_to_2" in sc else "-"),
        x_1_to_4=(f"{sc['scaling_1_to_4']:.2f}"
                  if "scaling_1_to_4" in sc else "-"))]


def run_scaling_only():
    """Standalone replica-scaling sweep: autotune each family, measure its
    real wave service time, and run the discrete-event sweep from that
    anchor — skipping the full load-curve bench."""
    banner("Serving: replica scaling (simulated pool, measured service)")
    entries = _build_entries(jax.random.PRNGKey(0), np.random.default_rng(0))
    rows = []
    doc = {"fast": FAST, "models": {}}
    for name, (cm, mk) in entries.items():
        cfg = autotune_model(cm, batch=32 if FAST else 64)
        cm.apply_tuned(cfg)
        mb = cm.default_micro_batch
        sc = bench_scaling(name, measure_wave_service_s(cm, mb), mb)
        doc["models"][name] = sc
        rows.extend(_scaling_rows(name, sc))
    print_rows(rows)
    emit_json("BENCH_serving_scaling.json", doc)
    return rows


def run():
    banner("Serving: throughput-at-SLO over the dynamic-batching router")
    n_queries = 48 if FAST else 128
    entries = _build_entries(jax.random.PRNGKey(0), np.random.default_rng(0))

    rows = []
    doc = {"models": {}, "scaling": {}, "fast": FAST,
           "load_fractions": list(LOAD_FRACTIONS),
           "shed_ceiling": SHED_CEILING}
    for name, (cm, mk) in entries.items():
        res = bench_model(name, cm, mk, n_queries)
        doc["models"][name] = res
        # replica-scaling sweep anchored to this family's measured wave
        # service (pinned to the saturation probe above)
        sc = bench_scaling(name, res["wave_service_ms"] / 1e3,
                           res["micro_batch"])
        doc["scaling"][name] = sc
        rows.extend(_scaling_rows(name, sc))
        for c in res["curve"]:
            rows.append(row(
                f"serve/{name}/load{c['load_fraction']:.1f}",
                c["p99_ms"] * 1e3,
                offered_qps=f"{c['offered_qps']:.0f}",
                achieved_qps=f"{c['achieved_qps']:.0f}",
                p99_ms=f"{c['p99_ms']:.3f}",
                budget_ms=f"{res['p99_budget_ms']:.1f}",
                shed_rate=f"{c['shed_rate']:.3f}",
                occupancy=f"{c['wave_occupancy']:.2f}",
                met_slo=c["met_slo"],
                bit_exact=c["bit_exact_vs_offline"]))
        op = res["operating_point"]
        rows.append(row(
            f"serve/{name}/operating_point", 0.0,
            micro_batch=res["micro_batch"],
            budget_ms=f"{res['p99_budget_ms']:.1f}",
            saturation_qps=f"{res['measured_saturation_qps']:.0f}",
            qps_at_slo=("-" if op is None
                        else f"{op['achieved_qps']:.0f}"),
            at_load=("-" if op is None else op["load_fraction"])))
    # degraded-capacity row: 1-of-2 replicas killed at t=half, anchored to
    # the first family's measured wave service (the fault machinery is
    # model-agnostic; one exact simulated row tracks it across PRs)
    anchor = next(iter(doc["models"]))
    flt = bench_faults(doc["models"][anchor]["wave_service_ms"] / 1e3,
                       doc["models"][anchor]["micro_batch"])
    doc["faults"] = {"anchor_model": anchor, **flt}
    rows.append(row(
        "serve/faults/kill_1of2", 0.0,
        offered_qps=f"{flt['offered_qps']:.0f}",
        pre_p99_ms=(f"{flt['pre_kill']['p99_ms']:.3f}"
                    if flt["pre_kill"]["p99_ms"] is not None else "-"),
        post_p99_ms=(f"{flt['post_kill']['p99_ms']:.3f}"
                     if flt["post_kill"]["p99_ms"] is not None else "-"),
        post_shed=f"{flt['post_kill']['shed_rate']:.3f}",
        quarantined=flt["killed_replica_quarantined"],
        zero_lost=flt["zero_lost"]))
    # cold-start row: the anchor family served as if NEVER measured —
    # admission priced by the fleet predictor (trained on the other
    # families' anchors) from wave 0, vs today's unpriced cold start
    pred_s = _fleet_predicted_service_s(entries, doc["models"], anchor)
    cold = bench_cold_start(doc["models"][anchor]["wave_service_ms"] / 1e3,
                            pred_s, doc["models"][anchor]["micro_batch"])
    doc["cold_start"] = {"anchor_model": anchor, **cold}
    rows.append(row(
        "serve/cold_start/predicted_vs_unpriced", 0.0,
        predicted_ms=f"{cold['predicted_wave_ms']:.3f}",
        true_ms=f"{cold['wave_service_ms']:.3f}",
        pred_err=f"{cold['prediction_rel_err']:.2f}",
        priced_p99_ms=(f"{cold['predicted']['p99_ms']:.3f}"
                       if cold["predicted"]["p99_ms"] is not None else "-"),
        unpriced_p99_ms=(f"{cold['unpriced']['p99_ms']:.3f}"
                         if cold["unpriced"]["p99_ms"] is not None else "-"),
        shed_delta=f"{cold['shed_rate_delta']:+.3f}",
        priced_met_slo=cold["predicted"]["met_slo"]))
    print_rows(rows)
    emit_json("BENCH_serving.json", doc)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scaling", action="store_true",
                    help="run only the replica-scaling sweep "
                         "(emits BENCH_serving_scaling.json)")
    if ap.parse_args().scaling:
        run_scaling_only()
    else:
        run()
