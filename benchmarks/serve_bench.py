"""Serving benchmark: throughput-at-SLO curves over the dynamic batcher.

The first benchmark gated on *tail latency under load* rather than
single-query speed: every Table-1 model is compiled, autotuned, given an
SLO-constrained operating point (``deploy.autotune.slo_micro_batch`` — the
largest wave whose modeled fill+drain fits the p99 budget), and then
driven through the ``repro.serve`` router with Poisson arrivals at a sweep
of load fractions of its modeled saturation throughput. Each point reports
p50/p90/p99 latency, achieved throughput, shed rate, and wave occupancy —
and asserts the wave-padding contract by checking every served result
bit-exact against ``offline`` (``server_streaming`` does the comparison,
padded partial waves included).

The **operating point** per model is the largest swept load whose p99
stayed inside the budget with shed rate < 1% — the "throughput at SLO"
number a capacity planner would quote. Everything lands machine-readable
in ``BENCH_serving.json`` (``REPRO_BENCH_DIR``) next to the scenario and
kernel artifacts so the serving trajectory is tracked across PRs.

Set REPRO_FAST=1 for a reduced-size pass (CI / smoke).
"""

from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import banner, emit_json, print_rows, row
from benchmarks.table6_scenarios import _compile_conv, _compile_mlp
from repro.deploy.autotune import autotune_model
from repro.deploy.scenarios import server_streaming
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP
from repro.serve import (
    ServiceModel,
    measure_wave_service_s,
    slo_operating_point,
)

FAST = os.environ.get("REPRO_FAST", "0") not in ("0", "")

#: Swept offered-load fractions of the modeled saturation throughput.
LOAD_FRACTIONS = (0.7, 1.1) if FAST else (0.3, 0.5, 0.7, 0.9, 1.1)

#: Shed-rate ceiling for a load point to count as "inside SLO".
SHED_CEILING = 0.01


def _budget_ms(service: ServiceModel, micro_batch: int) -> float:
    """Per-model p99 budget: 6x the modeled tuned-wave service time,
    floored at 10 ms. Derived (not hard-coded) so the same bench stays
    meaningful across machines an order of magnitude apart."""
    return max(10.0, 6.0 * service.wave_service_s(micro_batch) * 1e3)


def bench_model(name: str, cm, mk, n_queries: int):
    cfg = autotune_model(cm, batch=32 if FAST else 64)
    cm.apply_tuned(cfg)
    # model-first service estimate, pinned to reality by ONE measured wave
    # probe at the tuned wave size — stage compute alone misses the
    # per-wave dispatch overhead that dominates small models on CPU, and a
    # capacity plan from the raw model would sweep pure overload
    service = ServiceModel.from_compiled(cm, probe_batch=8)
    tuned_mb = cm.default_micro_batch
    service = service.recalibrated(
        measure_wave_service_s(cm, tuned_mb), tuned_mb)
    budget = _budget_ms(service, tuned_mb)
    # the wave's own service may take at most ~25% of the budget: the
    # admission estimate adds the batching wait (1.5x service below) and
    # queued waves on top, and est(empty queue) must clear the budget or
    # the controller sheds everything before the first wave forms.
    # Fixed-point-iterate the choice: dispatch overhead is flat across
    # wave sizes, so a model calibrated at the tuned wave is optimistic
    # about smaller waves — re-measure at the chosen wave until it
    # settles, and the modeled saturation the sweep scales is honest.
    point = slo_operating_point(service, 0.25 * budget)
    mb = int(point["micro_batch"])
    for _ in range(2):
        service = service.recalibrated(measure_wave_service_s(cm, mb), mb)
        point = slo_operating_point(service, 0.25 * budget)
        if int(point["micro_batch"]) == mb:
            break
        mb = int(point["micro_batch"])
    # deadline long enough that full waves can form at sub-saturation load
    max_wait_ms = max(2.0, 1.5 * service.wave_service_s(mb) * 1e3)

    # honest saturation: drive the router itself far past the modeled
    # peak with shedding off — back-to-back full waves through the real
    # dispatch loop (router bookkeeping included) — and read the achieved
    # throughput back as the capacity the sweep scales. The service model
    # is pinned to that number too, so the admission controller and the
    # offered load agree about what a wave really costs end to end.
    probe = server_streaming(
        cm, mk, qps=3.0 * service.saturation_qps(mb),
        n_queries=n_queries, seed=17, max_wait_ms=max_wait_ms,
        micro_batch=mb, warmup=1)
    sat_qps = probe.throughput_qps
    service = service.recalibrated(mb / sat_qps, mb)
    budget = max(budget, 3.5 * service.wave_service_s(mb) * 1e3)
    max_wait_ms = max(2.0, 1.5 * service.wave_service_s(mb) * 1e3)

    curve = []
    for frac in LOAD_FRACTIONS:
        rep = server_streaming(
            cm, mk, qps=frac * sat_qps, n_queries=n_queries,
            seed=int(frac * 100), max_wait_ms=max_wait_ms,
            p99_budget_ms=budget, micro_batch=mb, service_model=service)
        curve.append({
            "load_fraction": frac,
            "offered_qps": rep.extras["offered_qps"],
            "achieved_qps": rep.throughput_qps,
            "p50_ms": rep.p50_ms, "p90_ms": rep.p90_ms, "p99_ms": rep.p99_ms,
            "shed_rate": rep.extras["shed_rate"],
            "served": rep.extras["served"], "shed": rep.extras["shed"],
            "wave_occupancy": rep.extras["wave_occupancy"],
            "met_slo": rep.extras["met_slo"],
            "bit_exact_vs_offline": rep.extras.get("bit_exact_vs_offline"),
        })

    inside = [c for c in curve
              if c["met_slo"] and c["shed_rate"] < SHED_CEILING]
    op = max(inside, key=lambda c: c["achieved_qps"]) if inside else None
    return {
        "micro_batch": mb,
        "p99_budget_ms": budget,
        "max_wait_ms": max_wait_ms,
        "measured_saturation_qps": sat_qps,
        "service_calibration": service.calibration,
        "slo_candidates": point["candidates"],
        "curve": curve,
        "operating_point": op,
    }


def run():
    banner("Serving: throughput-at-SLO over the dynamic-batching router")
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    n_queries = 48 if FAST else 128

    entries = {}
    kws, ad = KWSMLP(), ADAutoencoder()
    for name, model, dim in (("KWS-FINN", kws, 490), ("AD-hls4ml", ad, 128)):
        cm = _compile_mlp(model, key)
        mk = (lambda d: lambda i: rng.integers(
            -127, 128, (d,)).astype(np.int32))(dim)
        entries[name] = (cm, mk)
    for name, model in (("IC-hls4ml", ICModel()), ("IC-FINN-CNV", CNVModel())):
        cm = _compile_conv(model, key, rng)
        hw, ch = model.in_hw, model.in_ch
        mk = (lambda h, c: lambda i: rng.integers(
            -127, 128, (h, h, c)).astype(np.int32))(hw, ch)
        entries[name] = (cm, mk)

    rows = []
    doc = {"models": {}, "fast": FAST,
           "load_fractions": list(LOAD_FRACTIONS),
           "shed_ceiling": SHED_CEILING}
    for name, (cm, mk) in entries.items():
        res = bench_model(name, cm, mk, n_queries)
        doc["models"][name] = res
        for c in res["curve"]:
            rows.append(row(
                f"serve/{name}/load{c['load_fraction']:.1f}",
                c["p99_ms"] * 1e3,
                offered_qps=f"{c['offered_qps']:.0f}",
                achieved_qps=f"{c['achieved_qps']:.0f}",
                p99_ms=f"{c['p99_ms']:.3f}",
                budget_ms=f"{res['p99_budget_ms']:.1f}",
                shed_rate=f"{c['shed_rate']:.3f}",
                occupancy=f"{c['wave_occupancy']:.2f}",
                met_slo=c["met_slo"],
                bit_exact=c["bit_exact_vs_offline"]))
        op = res["operating_point"]
        rows.append(row(
            f"serve/{name}/operating_point", 0.0,
            micro_batch=res["micro_batch"],
            budget_ms=f"{res['p99_budget_ms']:.1f}",
            saturation_qps=f"{res['measured_saturation_qps']:.0f}",
            qps_at_slo=("-" if op is None
                        else f"{op['achieved_qps']:.0f}"),
            at_load=("-" if op is None else op["load_fraction"])))
    print_rows(rows)
    emit_json("BENCH_serving.json", doc)
    return rows


if __name__ == "__main__":
    run()
