"""Paper Fig. 3: adaptive-ASHA scan of CNV variants in the (inference cost C,
accuracy) plane, with Eq. 2's cost normalized to the CNV-W1A1 reference.

Cost C is computed with the REAL BOPs/WM model (Eqs. 1-2); the accuracy axis
is the same calibrated surrogate family as fig2 (dataset offline). The
paper's finding to reproduce: CNV-W1A1 (C=1) sits essentially on the front."""

from __future__ import annotations

import math

import numpy as np

from benchmarks.common import banner, print_rows, row
from repro.core.bops import ModelCost, conv_cost, dense_cost
from repro.core.search import Choice, asha_search, pareto_front, \
    predictor_sweep
from repro.costmodel import features_from_model_cost, load_default


def cnv_cost(channels_scale, fc_units, w_bits, a_bits) -> ModelCost:
    chans = [int(64 * channels_scale), int(64 * channels_scale),
             int(128 * channels_scale), int(128 * channels_scale),
             int(256 * channels_scale), int(256 * channels_scale)]
    layers, cin, hw = [], 3, 32
    for i, ch in enumerate(chans):
        hw -= 2
        layers.append(conv_cost(f"c{i}", cin, max(ch, 1), 3, hw, hw,
                                8 if i == 0 else a_bits, w_bits, bias=False))
        if i in (1, 3):
            hw //= 2
        cin = max(ch, 1)
    dims = [cin, fc_units, fc_units, 10]
    for i in range(3):
        layers.append(dense_cost(f"f{i}", dims[i], dims[i + 1], a_bits,
                                 w_bits, bias=False))
    return ModelCost(layers)


REF = cnv_cost(1.0, 512, 1, 1)     # CNV-W1A1


def surrogate_accuracy(cfg, budget, rng):
    scale, fc, wb, ab = (cfg["scale"], cfg["fc"], cfg["w_bits"], cfg["a_bits"])
    acc = 0.86
    acc -= 0.10 * math.exp(-scale * 2.2)
    acc -= 0.05 * math.exp(-fc / 120.0)
    acc += 0.012 * (wb - 1) + 0.012 * (ab - 1)     # 2-bit slightly better
    return acc + rng.normal(0, 0.03 / math.sqrt(budget))


def run():
    banner("Fig 3: ASHA scan of CNV variants (accuracy x inference cost C)")
    space = [
        Choice("scale", (0.25, 0.5, 1.0, 2.0)),
        Choice("fc", (16, 64, 128, 256, 512)),
        Choice("w_bits", (1, 2)),
        Choice("a_bits", (1, 2)),
    ]
    best, trials = asha_search(surrogate_accuracy, space, n_trials=48,
                               r_min=1, eta=2, max_rung=4, seed=0)
    pts = []
    for t in trials:
        c = cnv_cost(t.config["scale"], t.config["fc"], t.config["w_bits"],
                     t.config["a_bits"]).cost_vs(REF)
        pts.append((c, t.score))
    front = pareto_front(pts)

    # where does CNV-W1A1 (cost exactly 1.0) sit relative to the front?
    rng = np.random.default_rng(0)
    cnv_acc = surrogate_accuracy({"scale": 1.0, "fc": 512, "w_bits": 1,
                                  "a_bits": 1}, 16, rng)
    dominators = [p for p in pts if p[0] <= 1.0 and p[1] > cnv_acc + 0.01]

    rows = [row(
        "fig3/asha_scan",
        n_trials=len(trials),
        total_budget=sum(t.budget_used for t in trials),
        best_score=f"{best.score:.3f}",
        best_cost_C=f"{cnv_cost(best.config['scale'], best.config['fc'], best.config['w_bits'], best.config['a_bits']).cost_vs(REF):.2f}",
        pareto_points=len(front),
        cnv_w1a1_cost=1.0,
        cnv_near_optimal=(len(dominators) <= 3),
        paper_finding="CNV-W1A1 performs near optimally",
    )]

    # -- predictor-evaluated codesign sweep: quantization x architecture x
    # serving micro-batch, ranked by the learned wave-cost predictor — the
    # Fig. 3 scan re-run without wall-clock (ROADMAP direction 5). ASHA's
    # rungs degenerate to one evaluation each (predictions are exact), but
    # the promotion bookkeeping is exercised on the predictor objective.
    predictor = load_default()
    codesign_space = space + [Choice("micro_batch", (1, 4, 16, 64))]

    def feature_fn(cfg):
        mc = cnv_cost(cfg["scale"], cfg["fc"], cfg["w_bits"], cfg["a_bits"])
        return features_from_model_cost(mc, cfg["micro_batch"],
                                        n_conv_stages=6)

    sweep = predictor_sweep(
        predictor.predict_ms, feature_fn, codesign_space, method="asha",
        n_trials=64, seed=0,
        accuracy_fn=lambda cfg: surrogate_accuracy(
            cfg, 10**8, np.random.default_rng(0)))
    best_pred = sweep["best"]
    rows.append(row(
        "fig3/predictor_codesign_sweep",
        n_evaluated=sweep["n_evaluated"],
        best_cfg=(f"x{best_pred['config']['scale']}"
                  f"fc{best_pred['config']['fc']}"
                  f"w{best_pred['config']['w_bits']}"
                  f"a{best_pred['config']['a_bits']}"
                  f"mb{best_pred['config']['micro_batch']}"),
        best_predicted_ms=f"{best_pred['predicted_ms']:.3f}",
        pareto_points=len(sweep["pareto"]),
        note="learned-cost sweep, zero wall-clock evaluations"))
    print_rows(rows)
    return rows


if __name__ == "__main__":
    run()
