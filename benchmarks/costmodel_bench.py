"""Learned wave-cost predictor benchmark: LOMO error vs the analytic FIFO
model + probed-vs-predicted autotune agreement (``BENCH_costmodel.json``).

The rule4ml loop (ROADMAP direction 5), measured end to end:

  1. **Harvest** — traced ``server_streaming`` runs of the golden families
     at several wave sizes record every dispatched wave's measured service
     next to the analytic FIFO prediction
     (``obs.report.prediction_records``); a probe-mode autotune pass per
     family contributes its audit-trail probes. ``repro.costmodel.dataset``
     joins both into the deterministic training table (saved next to the
     bench artifacts, plus the raw JSONL trace shards).
  2. **Validate (LOMO)** — hold each family out, train the predictor on the
     rest, score the held-out waves. The **asserted** acceptance bar:
     pooled median absolute relative error of the learned predictor <= the
     analytic FIFO model's on the same waves (the same error the obs bench
     publishes in ``BENCH_obs.json``) — the learned model must beat the
     hand-built baseline it bootstraps from, on families it never saw.
  3. **Agreement** — autotune each family twice: probe mode (measured
     refinement) vs model mode (probe-free, predictor trained on the full
     table). Where the chosen (micro_batch, segment_mode) match, agreement
     is exact by construction; where they differ, both configs are probed
     and the predicted config must hold >= 90% of the probed config's
     throughput (**asserted** — the probe-free mode is only useful if its
     configs are not left on the table).

Set REPRO_FAST=1 for a reduced-size pass (CI / smoke: 2 families).
"""

from __future__ import annotations

import os
import tempfile

import jax
import numpy as np

from benchmarks.common import banner, bench_dir, emit_json, print_rows, row
from benchmarks.table6_scenarios import _compile_conv, _compile_mlp
from repro.costmodel import (WaveCostPredictor, build_dataset,
                             compiled_feature_resolver, leave_one_model_out)
from repro.deploy.autotune import (autotune_model, load_config,
                                   probe_streaming, schedule_key)
from repro.deploy.scenarios import server_streaming
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP
from repro.obs import Tracer, export_prediction_records
from repro.obs.report import prediction_records
from repro.serve import ServiceModel, measure_wave_service_s

FAST = os.environ.get("REPRO_FAST", "0") not in ("0", "")

#: Throughput the predicted config must hold vs the probed config where
#: the two disagree (asserted).
MIN_AGREEMENT_TPUT = 0.9


def _build_entries(key, rng):
    entries = {}
    for name, model, dim in (("KWS-FINN", KWSMLP(), 490),
                             ("AD-hls4ml", ADAutoencoder(), 128)):
        cm = _compile_mlp(model, key)
        mk = (lambda d: lambda i: rng.integers(
            -127, 128, (d,)).astype(np.int32))(dim)
        entries[name] = (cm, mk)
    if not FAST:
        for name, model in (("IC-hls4ml", ICModel()),
                            ("IC-FINN-CNV", CNVModel())):
            cm = _compile_conv(model, key, rng)
            hw, ch = model.in_hw, model.in_ch
            mk = (lambda h, c: lambda i: rng.integers(
                -127, 128, (h, h, c)).astype(np.int32))(hw, ch)
            entries[name] = (cm, mk)
    return entries


def _traced_records(name, cm, mk, micro_batch, n_queries):
    """One traced server run at a forced wave size -> labeled trace rows
    (the obs bench's harvest, retagged with the family name — the router
    registers every model under the lane key)."""
    tracer = Tracer()
    cm.set_tracer(tracer)
    service = ServiceModel.from_compiled(cm, probe_batch=8)
    service = service.recalibrated(
        measure_wave_service_s(cm, micro_batch), micro_batch)
    try:
        server_streaming(
            cm, mk, qps=0.7 * service.saturation_qps(micro_batch),
            n_queries=n_queries, seed=7,
            max_wait_ms=max(2.0, 1.5 * service.wave_service_s(micro_batch)
                            * 1e3),
            micro_batch=micro_batch, service_model=service, tracer=tracer)
    finally:
        cm.set_tracer(None)
    records = []
    for r in prediction_records(tracer):
        records.append({**r, "model": name, "micro_batch": micro_batch})
    return records, tracer


def run():
    banner("Cost model: LOMO error vs analytic FIFO + autotune agreement")
    entries = _build_entries(jax.random.PRNGKey(0),
                             np.random.default_rng(0))
    n_queries = 32 if FAST else 64
    cache = tempfile.mkdtemp(prefix="repro_costmodel_autotune_")

    rows, trace_records, tuned_configs = [], [], []
    # -- harvest: traced serves at several wave sizes + audit trails ------
    for name, (cm, mk) in entries.items():
        cfg = autotune_model(cm, batch=32 if FAST else 64, mode="probe",
                             directory=cache, force=True)
        tuned_configs.append(cfg)
        cm.apply_tuned(cfg)
        waves = sorted({cfg.micro_batch, max(1, cfg.micro_batch // 4), 32})
        for mb in waves:
            recs, tracer = _traced_records(name, cm, mk, mb, n_queries)
            trace_records.extend(recs)
            export_prediction_records(
                tracer, os.path.join(
                    bench_dir(), f"COSTMODEL_trace_{name}_mb{mb}.jsonl"))
        rows.append(row(f"costmodel/{name}/harvest", 0.0,
                        waves=",".join(str(w) for w in waves),
                        trace_rows=len([r for r in trace_records
                                        if r["model"] == name]),
                        tuned_mb=cfg.micro_batch,
                        segment_mode=cfg.segment_mode))

    resolver = compiled_feature_resolver(
        {name: cm for name, (cm, mk) in entries.items()})
    dataset = build_dataset(resolver, trace_records=trace_records,
                            tuned_configs=tuned_configs)
    table_path = dataset.save(os.path.join(bench_dir(),
                                           "COSTMODEL_dataset.json"))
    doc = {"fast": FAST, "n_rows": len(dataset.rows),
           "models": dataset.models(), "dataset_path": table_path,
           "lomo": {}, "agreement": {}}

    # -- LOMO: the learned model vs the analytic baseline (asserted) ------
    lomo = leave_one_model_out(dataset.rows, l2=1e-2, seed=0, n_members=8)
    doc["lomo"] = lomo
    for held, stats in sorted(lomo.items()):
        if held == "overall":
            continue
        rows.append(row(
            f"costmodel/{held}/lomo", 0.0, n=stats["n"],
            learned_med=f"{stats['median_abs_rel_err']:.3f}",
            analytic_med=(f"{stats['analytic_median_abs_rel_err']:.3f}"
                          if "analytic_median_abs_rel_err" in stats
                          else "-")))
    overall = lomo["overall"]
    rows.append(row(
        "costmodel/overall/lomo", 0.0, n=overall["n"],
        learned_med=f"{overall['median_abs_rel_err']:.3f}",
        analytic_med=f"{overall['analytic_median_abs_rel_err']:.3f}"))
    assert (overall["median_abs_rel_err"]
            <= overall["analytic_median_abs_rel_err"]), (
        f"learned LOMO median abs rel err "
        f"{overall['median_abs_rel_err']:.3f} worse than the analytic "
        f"FIFO model's {overall['analytic_median_abs_rel_err']:.3f} — "
        "the predictor no longer beats the baseline it trains against")

    # -- agreement: probe-mode vs model-mode autotune (asserted) ----------
    predictor = WaveCostPredictor.fit_rows(dataset.rows, l2=1e-2, seed=0,
                                           n_members=8)
    for name, (cm, mk) in entries.items():
        probed = load_config(schedule_key(cm), directory=cache)
        predicted = autotune_model(cm, batch=32 if FAST else 64,
                                   mode="model", predictor=predictor,
                                   directory=tempfile.mkdtemp(
                                       prefix="repro_costmodel_model_"),
                                   force=True)
        match = (probed.micro_batch == predicted.micro_batch
                 and probed.segment_mode == predicted.segment_mode)
        entry = {
            "probed": {"micro_batch": probed.micro_batch,
                       "segment_mode": probed.segment_mode},
            "predicted": {"micro_batch": predicted.micro_batch,
                          "segment_mode": predicted.segment_mode},
            "config_match": match,
        }
        if match:
            entry["throughput_ratio"] = 1.0   # identical config, by
            # construction — re-timing the same program twice would only
            # measure machine noise
        else:
            batch = 64
            x = None
            from repro.deploy.autotune import default_sample

            x = default_sample(cm, batch)
            t = {}
            for label, cfg in (("probed", probed),
                               ("predicted", predicted)):
                cm.apply_tuned(cfg)
                t[label] = probe_streaming(cm, x, cfg.micro_batch, iters=3)
            cm.apply_tuned(probed)
            entry["throughput_ratio"] = t["probed"] / t["predicted"]
        doc["agreement"][name] = entry
        rows.append(row(
            f"costmodel/{name}/agreement", 0.0,
            probed_mb=probed.micro_batch, predicted_mb=predicted.micro_batch,
            probed_mode=probed.segment_mode,
            predicted_mode=predicted.segment_mode,
            match=match, tput_ratio=f"{entry['throughput_ratio']:.3f}",
            source=predicted.source))
        assert entry["throughput_ratio"] >= MIN_AGREEMENT_TPUT, (
            f"{name}: predicted config holds only "
            f"{entry['throughput_ratio']:.2f}x of the probed config's "
            f"throughput (< {MIN_AGREEMENT_TPUT}) — the probe-free mode "
            "is leaving performance on the table")

    print_rows(rows)
    emit_json("BENCH_costmodel.json", doc)
    return rows


if __name__ == "__main__":
    run()
