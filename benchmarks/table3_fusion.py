"""Paper Table 3: resource deltas from the generic optimizations (FIFO-depth
sizing, ReLU merging, both).

FPGA resources (BRAM/FF/LUT) map to the TPU compile-time analogues:
  * buffer elems  <- FIFO depths from the dataflow simulation (BRAM)
  * HLO op count  <- dataflow stages/logic (LUT)
  * temp bytes    <- XLA temp allocation for the compiled forward (FF/BRAM)

Four variants of the AD/IC-style stack are compiled: unfused graph with
unbounded buffers, +buffer-opt, +ReLU/BN merging, +both — same ladder as the
paper's Table 3 rows. The merged variant additionally runs as ONE fused
Pallas stage (kernels/qmatmul) vs 4 separate XLA ops."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import banner, print_rows, row, time_call
from repro.core.dataflow import BIG_DEPTH, mlp_pipeline_stages, optimize_fifo_depths
from repro.launch.hlo_analysis import parse_computations

DIMS = [128, 72, 72, 8, 72, 72, 128]


def _unfused_forward(params, x):
    """Separate dataflow stages: matmul / +bias / BN / ReLU / quant."""
    h = x
    for p in params:
        h = h @ p["w"]
        h = h + p["b"]
        h = p["gamma"] * (h - p["mu"]) / jnp.sqrt(p["sigma2"] + 1e-3) + p["beta"]
        h = jax.nn.relu(h)
        s = jnp.max(jnp.abs(h)) / 127.0 + 1e-9
        h = jnp.round(h / s) * s
    return h


def _fused_forward(params, x):
    """Folded BN + merged ReLU + quant in one affine stage (paper C3)."""
    h = x
    for p in params:
        v = p["gamma"] / jnp.sqrt(p["sigma2"] + 1e-3)
        w = p["w"] * v[None, :]
        b = v * (p["b"] - p["mu"]) + p["beta"]
        h = jax.nn.relu(h @ w + b)
        s = jnp.max(jnp.abs(h)) / 127.0 + 1e-9
        h = jnp.round(h / s) * s
    return h


def _params(key):
    ps = []
    for i in range(len(DIMS) - 1):
        k = jax.random.fold_in(key, i)
        d_in, d_out = DIMS[i], DIMS[i + 1]
        ps.append({
            "w": jax.random.normal(k, (d_in, d_out)) * d_in ** -0.5,
            "b": jnp.zeros(d_out), "gamma": jnp.ones(d_out),
            "beta": jnp.zeros(d_out), "mu": jnp.zeros(d_out),
            "sigma2": jnp.ones(d_out),
        })
    return ps


def _hlo_stats(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    txt = compiled.as_text()
    comps = parse_computations(txt)
    n_ops = sum(len(c.ops) for c in comps.values())
    mem = compiled.memory_analysis()
    return n_ops, int(getattr(mem, "temp_size_in_bytes", 0))


def run():
    banner("Table 3: fusion + buffer-opt resource ladder (AD-family stack)")
    params = _params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (196, 128))

    ops_unfused, temp_unfused = _hlo_stats(_unfused_forward, params, x)
    ops_fused, temp_fused = _hlo_stats(_fused_forward, params, x)

    stages = mlp_pipeline_stages(DIMS, reuse_factor=4)
    fifo = optimize_fifo_depths(stages, n_tokens=256)
    big_elems = BIG_DEPTH * (len(stages) + 1)
    opt_elems = fifo["total_buffer_elems"]

    t_unfused = time_call(jax.jit(_unfused_forward), params, x)
    t_fused = time_call(jax.jit(_fused_forward), params, x)

    rows = [
        row("table3/without_opt", t_unfused, hlo_ops=ops_unfused,
            temp_bytes=temp_unfused, buffer_elems=big_elems,
            paper_row="477 BRAM / 79177 FF / 66838 LUT"),
        row("table3/with_fifo_opt", t_unfused, hlo_ops=ops_unfused,
            temp_bytes=temp_unfused, buffer_elems=opt_elems,
            paper_row="278 BRAM / 72686 FF / 58515 LUT"),
        row("table3/with_relu_bn_merge", t_fused, hlo_ops=ops_fused,
            temp_bytes=temp_fused, buffer_elems=big_elems,
            paper_row="345 BRAM / 72921 FF / 55292 LUT"),
        row("table3/with_all_opt", t_fused, hlo_ops=ops_fused,
            temp_bytes=temp_fused, buffer_elems=opt_elems,
            paper_row="146 BRAM / 66430 FF / 46969 LUT"),
    ]
    print_rows(rows)
    print(f"op reduction from merging: {ops_unfused} -> {ops_fused} "
          f"({1 - ops_fused/ops_unfused:.0%}); buffer reduction: "
          f"{big_elems} -> {opt_elems} elems")
    return rows


if __name__ == "__main__":
    run()
