"""Observability benchmark: NullTracer overhead + prediction-error table.

Two numbers gate the obs layer (``repro.obs``):

  * **Disabled-path overhead** — the whole stack is permanently
    instrumented (router, executor segments, scenarios), with the
    ``NullTracer`` as the default sink. That is only acceptable if the
    disabled path is free: this bench times the Offline scenario pool
    through the instrumented executor against a bare uninstrumented loop
    over the same jitted program and **asserts** the ratio stays within
    2% (``MAX_NULL_OVERHEAD``). A regression here means someone put real
    work outside an ``if tracer.enabled:`` guard.
  * **FIFO-model prediction error** — a traced ``server_streaming`` run
    records every dispatched wave with the cost model's *predicted*
    service time next to its measured duration;
    ``obs.report.prediction_error`` aggregates mean/median relative error
    and signed bias per (model, platform). This table — published in
    ``BENCH_obs.json`` across runs — is the training set (and the number
    to beat) for a learned service-time predictor, ROADMAP direction 5.

The traced run is also exported as a Chrome trace-event timeline
(``TRACE_serve.json`` in ``REPRO_BENCH_DIR``) — load it at
ui.perfetto.dev: pid 0 is the router (lanes as threads), pid 1+i is
replica i (wave rows), counters carry backlog / occupancy / outstanding
work. ``python benchmarks/obs_bench.py --demo`` produces just the
timeline (the ``make trace-demo`` path).

Set REPRO_FAST=1 for a reduced-size pass (CI / smoke).
"""

from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import banner, bench_dir, emit_json, print_rows, row
from benchmarks.table6_scenarios import _compile_conv, _compile_mlp
from repro.deploy.scenarios import offline, server_streaming
from repro.models.tiny import ADAutoencoder, ICModel, KWSMLP
from repro.obs import Tracer, export_chrome, timer as obs_timer
from repro.obs.report import latency_percentiles, prediction_error
from repro.serve import ServiceModel, measure_wave_service_s

FAST = os.environ.get("REPRO_FAST", "0") not in ("0", "")

#: Disabled-path budget: instrumented-with-NullTracer may cost at most
#: this factor of the bare uninstrumented program on the Offline pool.
MAX_NULL_OVERHEAD = 1.02


def _null_overhead(cm, mk, n_samples: int, iters: int):
    """Disabled-path (NullTracer) overhead on the Offline pool.

    Two measurements land in the artifact:

    * ``overhead_ratio`` — the **asserted** number, built from parts that
      don't flap on machine noise: count the guarded instrumentation
      sites one ``streaming_compiled`` call actually executes (install a
      real tracer once, count events; each recorded event is one
      ``if tracer.enabled:`` site, evaluated ~2x on the disabled path),
      microbenchmark the disabled-path cost per site in a tight loop,
      and divide by the best-of-``iters`` bare pool time. A wall-clock
      A/B of two ~ms runs swings +-10% on a shared CPU — far above the
      2% budget being asserted — so the ratio is composed, not raced.
    * ``wall_ratio`` — the raw end-to-end A/B (instrumented entry point
      vs a bare loop replicating the pre-instrumentation schedule),
      reported for eyeballing but NOT asserted, for the reason above.
    """
    import jax.numpy as jnp

    from repro.obs.tracer import NULL_TRACER, Tracer as _Tracer

    xb = np.stack([mk(i) for i in range(n_samples)])
    mb = cm.default_micro_batch

    def bare_streaming():
        # streaming_compiled exactly as written before instrumentation:
        # pad, plan, one jit program per compiled segment, no tracer
        x_p, n, n_m = cm._pad_micro(xb, mb)
        cm.plan_streaming(n_m, micro_batch=mb)
        wave = x_p.reshape((n_m, mb) + x_p.shape[1:])
        for k, seg in enumerate(cm.segments):
            if seg.compiled:
                wave = cm._segment_fn(k)(wave)
            else:
                outs = [wave[i] for i in range(n_m)]
                for si in range(seg.start, seg.stop):
                    outs = [cm._stage_fns[si](h) for h in outs]
                wave = jnp.stack(outs)
        return wave.reshape((n_m * mb,) + wave.shape[2:])[:n]

    jax.block_until_ready(bare_streaming())                 # compile + warm
    jax.block_until_ready(cm.streaming_compiled(xb)[0])
    bare, instr = [], []
    for _ in range(iters):
        t0 = obs_timer.now()
        jax.block_until_ready(bare_streaming())
        bare.append(obs_timer.now() - t0)
        t0 = obs_timer.now()
        jax.block_until_ready(cm.streaming_compiled(xb)[0])
        instr.append(obs_timer.now() - t0)

    # sites executed per call: one recorded event per guarded site
    counting = _Tracer()
    cm.set_tracer(counting)
    cm.streaming_compiled(xb)
    n_sites = len(counting)
    cm.set_tracer(None)

    # disabled-path cost per site (enabled check + skipped branch),
    # ~2 guard evaluations per site (span start + record)
    null, reps = NULL_TRACER, 200_000
    t0 = obs_timer.now()
    for _ in range(reps):
        if null.enabled:
            pass                                 # pragma: no cover
        if null.enabled:
            pass                                 # pragma: no cover
    per_site_s = (obs_timer.now() - t0) / reps

    # the Offline scenario wrapper timed over the same jitted program —
    # its per-iteration guards are part of the scenario number itself
    rep = offline(cm.offline, mk, n_samples=n_samples, warmup=1,
                  iters=iters)
    scenario_s = n_samples / rep.throughput_qps

    return {
        "n_samples": n_samples,
        "iters": iters,
        "micro_batch": mb,
        "n_guarded_sites": n_sites,
        "per_site_ns": per_site_s * 1e9,
        "bare_streaming_ms": min(bare) * 1e3,
        "instrumented_null_ms": min(instr) * 1e3,
        "overhead_ratio": 1.0 + (n_sites * per_site_s) / min(bare),
        "wall_ratio": min(instr) / min(bare),
        "offline_scenario_ms": float(scenario_s) * 1e3,
        "budget_ratio": MAX_NULL_OVERHEAD,
    }


def _traced_serve(name: str, cm, mk, n_queries: int, tracer: Tracer):
    """One SystemClock server run through the router with tracing on,
    service model attached so every wave span carries ``predicted_ms``."""
    mb = cm.default_micro_batch
    service = ServiceModel.from_compiled(cm, probe_batch=8)
    service = service.recalibrated(measure_wave_service_s(cm, mb), mb)
    rep = server_streaming(
        cm, mk, qps=0.7 * service.saturation_qps(mb),
        n_queries=n_queries, seed=7,
        max_wait_ms=max(2.0, 1.5 * service.wave_service_s(mb) * 1e3),
        micro_batch=mb, service_model=service, tracer=tracer)
    return rep


def run():
    banner("Observability: NullTracer overhead + FIFO prediction error")
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    n_samples = 64 if FAST else 256
    iters = 5 if FAST else 7
    n_queries = 32 if FAST else 96

    entries = {}
    for name, model, dim in (("KWS-FINN", KWSMLP(), 490),
                             ("AD-hls4ml", ADAutoencoder(), 128)):
        cm = _compile_mlp(model, key)
        mk = (lambda d: lambda i: rng.integers(
            -127, 128, (d,)).astype(np.int32))(dim)
        entries[name] = (cm, mk)
    if not FAST:
        ic = ICModel()
        cm = _compile_conv(ic, key, rng)
        hw, ch = ic.in_hw, ic.in_ch
        entries["IC-hls4ml"] = (
            cm, (lambda h, c: lambda i: rng.integers(
                -127, 128, (h, h, c)).astype(np.int32))(hw, ch))

    rows = []
    doc = {"fast": FAST, "null_overhead": {}, "prediction_error": {},
           "span_percentiles": {}}

    # -- disabled-path overhead (asserted) --------------------------------
    name, (cm, mk) = next(iter(entries.items()))
    ov = _null_overhead(cm, mk, n_samples, iters)
    doc["null_overhead"][name] = ov
    rows.append(row(f"obs/{name}/null_overhead",
                    ov["instrumented_null_ms"] * 1e3,
                    bare_ms=f"{ov['bare_streaming_ms']:.3f}",
                    ratio=f"{ov['overhead_ratio']:.6f}",
                    wall_ratio=f"{ov['wall_ratio']:.4f}",
                    sites=ov["n_guarded_sites"],
                    budget=f"{MAX_NULL_OVERHEAD:.2f}"))
    assert ov["overhead_ratio"] <= MAX_NULL_OVERHEAD, (
        f"NullTracer overhead_ratio {ov['overhead_ratio']:.4f} exceeds "
        f"{MAX_NULL_OVERHEAD} on the Offline pool — check for "
        f"instrumentation outside `if tracer.enabled:` guards")

    # -- traced serve: prediction error + timeline ------------------------
    tracer = Tracer()
    trace_names = None
    for name, (cm, mk) in entries.items():
        cm.set_tracer(tracer)
        rep = _traced_serve(name, cm, mk, n_queries, tracer)
        cm.set_tracer(None)
        pcts = latency_percentiles(tracer, model="m")
        doc["span_percentiles"][name] = pcts
        rows.append(row(f"obs/{name}/traced_serve", rep.p99_ms * 1e3,
                        served=rep.extras["served"],
                        p99_ms=f"{rep.p99_ms:.3f}",
                        span_p99_ms=f"{pcts['p99_ms']:.3f}",
                        waves=rep.extras["n_waves"]))
        err = prediction_error(tracer)
        for group, stats in err.items():
            doc["prediction_error"][f"{name}:{group}"] = stats
            rows.append(row(
                f"obs/{name}/prediction_error",
                stats["predicted_ms_mean"] * 1e3,
                n_waves=stats["n_waves"],
                predicted_ms=f"{stats['predicted_ms_mean']:.3f}",
                measured_ms=f"{stats['measured_ms_mean']:.3f}",
                mean_abs_rel_err=f"{stats['mean_abs_rel_err']:.3f}",
                bias_rel=f"{stats['bias_rel']:+.3f}"))
        tracer.clear()      # one model per timeline section in the export

    # re-run the LAST model with the tracer kept, for the exported demo
    name, (cm, mk) = next(iter(entries.items()))
    cm.set_tracer(tracer)
    _traced_serve(name, cm, mk, n_queries, tracer)
    cm.set_tracer(None)
    path = export_chrome(
        tracer, os.path.join(bench_dir(), "TRACE_serve.json"),
        process_names={0: "router", 1: "replica0"})
    doc["trace_path"] = path
    doc["trace_events"] = len(tracer)
    rows.append(row("obs/trace_export", 0.0, path=path,
                    events=len(tracer)))

    print_rows(rows)
    emit_json("BENCH_obs.json", doc)
    return rows


def demo():
    """``make trace-demo``: one small SystemClock server run, exported as
    a Perfetto-loadable timeline (no asserts, no sweep)."""
    banner("Trace demo: one traced server run -> Perfetto timeline")
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    cm = _compile_mlp(KWSMLP(), key)
    mk = lambda i: rng.integers(-127, 128, (490,)).astype(np.int32)
    tracer = Tracer()
    cm.set_tracer(tracer)
    rep = _traced_serve("KWS-FINN", cm, mk, n_queries=32, tracer=tracer)
    path = export_chrome(
        tracer, os.path.join(bench_dir(), "TRACE_serve.json"),
        process_names={0: "router", 1: "replica0"})
    print(f"served={rep.extras['served']} waves={rep.extras['n_waves']} "
          f"p99_ms={rep.p99_ms:.3f}")
    print(f"timeline: {path} ({len(tracer)} events) — "
          f"open at https://ui.perfetto.dev")
    return path


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true",
                    help="just the traced-run timeline export")
    if ap.parse_args().demo:
        demo()
    else:
        run()
