"""Kernel-level benchmark: the fused Pallas dataflow stage vs the unfused
XLA op chain, plus roofline byte/FLOP accounting per kernel.

On this CPU container the Pallas kernels run in interpret mode (Python
semantics — wall times are meaningless), so the measured comparison is
unfused-XLA vs fused-XLA epilogue, and the Pallas win is reported
structurally: HBM traffic eliminated by fusion (the activation tensor
round-trips the fused stage saves), which is what moves the memory roofline
term on real hardware.

The conv section measures the two conv lowerings of one streamlined stage —
the fused direct-conv path (shifted-window taps, no patch matrix) vs the
im2col + threshold_matmul fallback — on their XLA fast paths, next to the
lowering-aware traffic model from ``core.bops.stage_cost`` (the im2col
matrix write+read the direct kernel never pays)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, emit_json, print_rows, row, time_call
from repro.core.bops import stage_cost
from repro.core.streamline import make_threshold_stage
from repro.deploy.autotune import plan_block_h
from repro.deploy.lower import (
    ConvGeom,
    FusedConvThresholdStage,
    _float_mm_safe,
)
from repro.kernels.ref import qmatmul_ref


def _unfused(x_int, w_int, scale, bias):
    acc = jax.lax.dot_general(x_int.astype(jnp.int32), w_int.astype(jnp.int32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32)          # stage 1 out
    y = y * scale[None, :]               # dequant stage
    y = y + bias[None, :]                # bias stage
    y = jnp.maximum(y, 0.0)              # relu stage
    q = jnp.round(y / 0.125)             # requant stage
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def run():
    banner("Kernel bench: fused dataflow stage (qmatmul) traffic accounting")
    rng = np.random.default_rng(0)
    M, K, N = 512, 512, 512
    x = jnp.asarray(rng.integers(-127, 128, (M, K)).astype(np.int8))
    w = jnp.asarray(rng.integers(-127, 128, (K, N)).astype(np.int8))
    s = jnp.asarray(rng.uniform(1e-3, 1e-2, N).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(N).astype(np.float32))

    t_unfused = time_call(jax.jit(_unfused), x, w, s, b)
    t_fused_xla = time_call(jax.jit(
        lambda x, w, s, b: qmatmul_ref(x, w, s, b, relu=True, out_scale=0.125)),
        x, w, s, b)

    # HBM traffic model: unfused writes/reads the (M,N) int32 accumulator and
    # the (M,N) f32 intermediate between stages; fused keeps both in VMEM.
    inter_stage_bytes = M * N * 4 * 2 * 2        # acc + f32, write+read
    io_bytes = M * K + K * N + N * 8 + M * N     # in/out tensors once
    rows = [
        row("kernel/qmatmul_unfused_xla", t_unfused,
            hbm_bytes_model=io_bytes + inter_stage_bytes),
        row("kernel/qmatmul_fused_xla_epilogue", t_fused_xla,
            hbm_bytes_model=io_bytes + inter_stage_bytes // 2),
        row("kernel/qmatmul_fused_pallas", 0.0,
            hbm_bytes_model=io_bytes,
            note="interpret-mode on CPU; traffic model only",
            traffic_saving=f"{inter_stage_bytes/(io_bytes+inter_stage_bytes):.0%}"),
    ]
    rows += _conv_lowering_bench(rng)
    rows += _megakernel_bench()
    print_rows(rows)
    emit_json("BENCH_kernels.json", {"rows": rows})
    return rows


def _conv_lowering_bench(rng):
    """Direct-conv vs im2col lowering of one streamlined conv stage."""
    banner("Kernel bench: fused direct-conv vs materialized im2col")
    h = w = 32
    c, f, k, bits = 16, 32, 3, 4
    w_int = jnp.asarray(rng.integers(-7, 8, (k * k * c, f)), jnp.int32)
    s_w = jnp.full((f,), 2.0 ** -4, jnp.float32)
    b = jnp.zeros((f,), jnp.float32)
    td = make_threshold_stage(w_int, s_w, b, in_scale=2.0 ** -5,
                              act_bits=bits, s_out=2.0 ** -3)
    geom = ConvGeom(kernel=k, stride=1, padding="SAME", in_h=h, in_w=w,
                    in_ch=c, out_h=h, out_w=w, out_ch=f)
    mm = _float_mm_safe(td.w_int, bits)
    mk = lambda kind: FusedConvThresholdStage(
        name=f"conv[{kind}]", stage=td, geom=geom, in_scale=2.0 ** -5,
        in_bits=bits, mm_float=mm, lowering=kind)
    direct, i2c = mk("direct"), mk("im2col")
    x = jnp.asarray(rng.integers(0, 2 ** bits, (8, h, w, c)), jnp.int32)
    f_direct = jax.jit(direct.apply_fast)
    f_i2c = jax.jit(i2c.apply_fast)
    assert bool(jnp.all(f_direct(x).reshape(-1) == f_i2c(x).reshape(-1)))
    t_direct = time_call(f_direct, x)
    t_i2c = time_call(f_i2c, x)
    traffic_d = stage_cost(direct).traffic_bytes
    traffic_i = stage_cost(i2c).traffic_bytes
    # the block_h model the autotuner runs: banded input bytes (halo rows
    # re-fetched per block) vs VMEM fit, per candidate row block
    plan = plan_block_h(geom)
    return [
        row("kernel/conv_threshold_direct", t_direct,
            hbm_bytes_model=int(traffic_d)),
        row("kernel/conv_threshold_im2col", t_i2c,
            hbm_bytes_model=int(traffic_i),
            im2col_bytes=int(traffic_i - traffic_d),
            direct_speedup=f"{t_i2c / max(t_direct, 1e-9):.2f}x"),
        row("kernel/conv_threshold_block_h", 0.0,
            tuned_block_h=plan["block_h"],
            banded_input_bytes=int(plan["input_bytes"]),
            candidates=";".join(
                f"{c['block_h']}:{int(c['input_bytes'])}"
                + ("" if c["fits_vmem"] else "!vmem")
                for c in plan["candidates"])),
    ]


def _megakernel_bench():
    """Staged lax.map dispatch vs the whole-network-resident megakernel.

    Head-to-head on the two MLP goldens (KWS and AD — the single-segment
    waves where the staged pipeline's speedup over the host loop used to
    flatline near 1.0x): both modes run the same compiled segment
    programs on the same pool, and must agree bit for bit. A deep wave
    (many small micro-batches) makes the per-micro-batch per-stage
    dispatch the staged path pays visible; best-of-N timing because this
    shared-CPU container's noise floor swamps a median at millisecond
    scale. Next to the measured speedup sits the residency traffic
    model's saving — the per-stage weight/bank re-fetches and inter-stage
    HBM round-trips the fused dispatch deletes (``docs/megakernel.md``)."""
    banner("Kernel bench: megakernel vs staged segment dispatch (KWS/AD)")
    import os
    import time

    import jax.random

    from repro.core.bops import megakernel_traffic_bytes, staged_traffic_bytes
    from repro.core.qir import export_qmlp
    from repro.deploy import compile_graph
    from repro.models.tiny import ADAutoencoder, KWSMLP

    fast = os.environ.get("REPRO_FAST", "0") not in ("0", "")
    batch, mb, iters = (256, 4, 3) if fast else (1024, 4, 7)

    def best(run, x):
        y, _ = run(x, micro_batch=mb)
        jax.block_until_ready(y)             # compile + warm
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            y, _ = run(x, micro_batch=mb)
            jax.block_until_ready(y)
            times.append(time.perf_counter() - t0)
        return min(times)

    rng = np.random.default_rng(2022)
    builds = {
        "kws": (KWSMLP(width=32), jax.random.PRNGKey(10), 490),
        "ad": (ADAutoencoder(width=24), jax.random.PRNGKey(11), 128),
    }
    rows = []
    for name, (model, key, in_dim) in builds.items():
        params = model.init(key)
        hidden, _ = model.layers()
        graph = export_qmlp(hidden, params["hidden"], params["head"],
                            meta={"model": name}, freeze_scales=True,
                            in_scale=1.0 / 127.0)
        cm = compile_graph(graph, in_scale=1.0 / 127.0, use_pallas=False)
        x = jnp.asarray(rng.integers(-127, 128, (batch, in_dim)), jnp.int32)

        cm.set_megakernel(False)
        y_staged, _ = cm.streaming_compiled(x, micro_batch=mb)
        t_staged = best(cm.streaming_compiled, x)

        cm.set_megakernel(True)
        assert cm._mega_plans, f"{name}: planner admitted no megakernel"
        plan = next(iter(cm._mega_plans.values()))
        y_mega, stats = cm.streaming_compiled(x, micro_batch=mb)
        assert stats.megakernel == [(plan.start, plan.stop)]
        t_mega = best(cm.streaming_compiled, x)
        assert bool(jnp.all(jnp.isclose(y_staged, y_mega, atol=1e-5))), name

        run_stages = cm.schedule.stages[plan.start:plan.stop]
        n_micro = -(-batch // mb)
        mega_b = megakernel_traffic_bytes(run_stages, batch)
        staged_b = n_micro * staged_traffic_bytes(run_stages, mb)
        rows.append(row(
            f"kernel/megakernel_{name}", t_mega * 1e6,
            staged_us=round(t_staged * 1e6, 1),
            megakernel_speedup=f"{t_staged / max(t_mega, 1e-9):.2f}x",
            batch=batch, micro_batch=mb,
            fused_stages=plan.n_stages,
            resident_bytes=plan.total_bytes,
            modeled_bytes_saved=int(staged_b - mega_b),
            hbm_bytes_model=int(mega_b)))
    return rows


if __name__ == "__main__":
    run()
