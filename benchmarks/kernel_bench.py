"""Kernel-level benchmark: the fused Pallas dataflow stage vs the unfused
XLA op chain, plus roofline byte/FLOP accounting per kernel.

On this CPU container the Pallas kernels run in interpret mode (Python
semantics — wall times are meaningless), so the measured comparison is
unfused-XLA vs fused-XLA epilogue, and the Pallas win is reported
structurally: HBM traffic eliminated by fusion (the activation tensor
round-trips the fused stage saves), which is what moves the memory roofline
term on real hardware."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, print_rows, row, time_call
from repro.kernels.ref import qmatmul_ref


def _unfused(x_int, w_int, scale, bias):
    acc = jax.lax.dot_general(x_int.astype(jnp.int32), w_int.astype(jnp.int32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32)          # stage 1 out
    y = y * scale[None, :]               # dequant stage
    y = y + bias[None, :]                # bias stage
    y = jnp.maximum(y, 0.0)              # relu stage
    q = jnp.round(y / 0.125)             # requant stage
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def run():
    banner("Kernel bench: fused dataflow stage (qmatmul) traffic accounting")
    rng = np.random.default_rng(0)
    M, K, N = 512, 512, 512
    x = jnp.asarray(rng.integers(-127, 128, (M, K)).astype(np.int8))
    w = jnp.asarray(rng.integers(-127, 128, (K, N)).astype(np.int8))
    s = jnp.asarray(rng.uniform(1e-3, 1e-2, N).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(N).astype(np.float32))

    t_unfused = time_call(jax.jit(_unfused), x, w, s, b)
    t_fused_xla = time_call(jax.jit(
        lambda x, w, s, b: qmatmul_ref(x, w, s, b, relu=True, out_scale=0.125)),
        x, w, s, b)

    # HBM traffic model: unfused writes/reads the (M,N) int32 accumulator and
    # the (M,N) f32 intermediate between stages; fused keeps both in VMEM.
    inter_stage_bytes = M * N * 4 * 2 * 2        # acc + f32, write+read
    io_bytes = M * K + K * N + N * 8 + M * N     # in/out tensors once
    rows = [
        row("kernel/qmatmul_unfused_xla", t_unfused,
            hbm_bytes_model=io_bytes + inter_stage_bytes),
        row("kernel/qmatmul_fused_xla_epilogue", t_fused_xla,
            hbm_bytes_model=io_bytes + inter_stage_bytes // 2),
        row("kernel/qmatmul_fused_pallas", 0.0,
            hbm_bytes_model=io_bytes,
            note="interpret-mode on CPU; traffic model only",
            traffic_saving=f"{inter_stage_bytes/(io_bytes+inter_stage_bytes):.0%}"),
    ]
    print_rows(rows)
    return rows


if __name__ == "__main__":
    run()
